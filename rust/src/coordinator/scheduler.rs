//! Multi-lane op scheduler — the submission path behind
//! [`crate::sd::backend::ShardedBackend`] and the serving rendezvous.
//!
//! Every operation reaches the coordinator as a typed
//! [`OpDesc`]: quantized ops route to IMAX lanes, everything else runs
//! on a bounded host pool sized like the A72 (2 cores). Because the host
//! workers also perform the marshalling (activation quantization) for
//! lane jobs, configuring more lanes than `host_threads` ceases to help
//! — the §V-A saturation, observable in this scheduler's metrics.
//!
//! Three lane entry points, all funneling through one `run_rows_on_lane`
//! primitive (so counters and phase accounting stay consistent):
//!
//! * [`Coordinator::submit_op`] — one op on one lane, selected
//!   residency-aware: a weight with a [`WeightId`] is routed to the lane
//!   that already holds (or was assigned) its cached tiles; anonymous
//!   weights round-robin.
//! * [`Coordinator::submit_sharded`] — **single-op multi-lane
//!   sharding**: the op's weight row-tiles are split across the lanes
//!   (see [`super::shard::ShardPlan`]), each lane computes and caches
//!   only its resident shard, and the per-shard outputs are stitched
//!   back column-wise — bit-identical to unsharded execution. This is
//!   what turns the per-lane weight cache into a bandwidth-scaling
//!   lever: aggregate resident bytes grow with the lane count, so the
//!   warm-step weight LOAD per lane shrinks as lanes are added.
//! * [`Coordinator::execute_coalesced`] — batched submission: jobs
//!   sharing a weight tensor have their activation rows concatenated
//!   into one lane submission (amortizing DMA setup, weight streaming
//!   and CONF/REGV/RANGE across requests); merged groups are ordered by
//!   kernel kind to avoid CONF reconfiguration.
//!
//! # Parallel shard execution
//!
//! With `host_threads > 1` the coordinator owns a
//! [`crate::util::pool::LanePool`] — one FIFO worker thread per lane —
//! and the sharded path splits into an asynchronous pair:
//! [`Coordinator::start_sharded`] marshals once, enqueues every shard on
//! its owning lane's queue and returns a [`PendingSharded`] ticket
//! immediately; [`Coordinator::join_sharded`] waits the per-shard
//! completion slots in shard order and stitches/books the results.
//! Shards of one op run concurrently across lanes, yet outputs and every
//! cycle/byte counter are **bit-identical** to the sequential path: each
//! lane's state evolves in enqueue order (per-lane FIFO), shard outputs
//! depend only on their operands, and all metrics are merged by the
//! joining thread in shard order. `DESIGN.md` ("Concurrency model")
//! documents the full argument.
//!
//! The compiled [`OpPlan`] seeds both routing modes before any op runs:
//! [`Coordinator::apply_plan`] shards *whole weights* across lanes
//! (kind-grouped so each lane sees one CONF kind where lane count
//! allows) and [`Coordinator::apply_plan_sharded`] pins each hot
//! weight's *row-tile shards* on their owning lanes.

use super::metrics::CoordinatorMetrics;
use super::offload::OffloadPolicy;
use super::shard::ShardPlan;
use crate::ggml::q3_k::BlockQ3K;
use crate::ggml::q8_0::BlockQ8_0;
use crate::ggml::{self, q8_0, q8_k, DType, Tensor, WeightId, QK8_0, QK_K};
use crate::imax::conf::{KernelConfig, KernelKind};
use crate::imax::lane::{weight_row_bytes, LaneSim};
use crate::imax::lmm::CacheStats;
use crate::imax::timing::PhaseBreakdown;
use crate::imax::ImaxConfig;
use crate::sd::backend::{OpDesc, OpKind};
use crate::sd::plan::OpPlan;
use crate::util::f16::F16;
use crate::util::pool::{CompletionSlot, LanePool};
use crate::util::sync::{rank, Mutex};
use std::collections::HashMap;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// One mat-mul job: quantized weights × f32 activations (the owned-
/// tensor form used by benches/examples; the serving layer submits
/// borrowed [`OpDesc`]s instead).
#[derive(Debug, Clone)]
pub struct MatMulJob {
    /// Job label (layer name).
    pub name: String,
    /// What the op is in the graph.
    pub kind: OpKind,
    /// Weight tensor.
    pub w: Arc<Tensor>,
    /// Activation tensor `[n, k]` f32.
    pub x: Arc<Tensor>,
}

/// Key identifying lane-batchable job shapes: jobs with equal keys run
/// the same kernel over the same weight geometry, so their lane
/// submissions can share a configuration — [`Coordinator::execute_coalesced`]
/// orders merged groups by this key (and merges jobs whose weight tensor
/// is additionally *identical* into a single batched submission).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ShapeKey {
    /// Weight dtype (selects the lane kernel).
    pub dtype: DType,
    /// Weight rows (output features).
    pub m: usize,
    /// Contraction length.
    pub k: usize,
}

impl MatMulJob {
    /// MAC count.
    pub fn macs(&self) -> u64 {
        (self.w.rows * self.w.cols * self.x.rows) as u64
    }

    /// Shape key for coalescing.
    pub fn shape_key(&self) -> ShapeKey {
        ShapeKey { dtype: self.w.dtype(), m: self.w.rows, k: self.w.cols }
    }

    /// The job as a borrowed typed op.
    pub fn as_op(&self) -> OpDesc<'_> {
        OpDesc::new(self.kind, &self.w, &self.x)
    }
}

/// Result of one sharded submission: the stitched output plus the
/// summed per-shard lane costs (what [`crate::sd::backend::ShardedBackend`]
/// folds into its [`crate::sd::backend::EngineStats`]).
#[derive(Debug)]
pub struct ShardedRun {
    /// Stitched `[n, m]` output, bit-identical to unsharded execution.
    pub out: Tensor,
    /// Phase breakdown summed over the shards.
    pub phases: PhaseBreakdown,
    /// Residency-cache deltas summed over the shards' lanes.
    pub cache: CacheStats,
    /// Lane submissions the op decomposed into.
    pub shards: usize,
}

/// Cumulative cost counters of one lane (see
/// [`Coordinator::lane_costs`]).
#[derive(Debug, Clone, Copy)]
pub struct LaneCost {
    /// Simulated cycles across all phases.
    pub cycles: u64,
    /// All DMA LOAD bytes (weights + activations).
    pub loaded_bytes: u64,
    /// DMA LOAD bytes spent on weight tiles only.
    pub weight_load_bytes: u64,
    /// Residency-cache counters.
    pub cache: CacheStats,
}

/// Pre-quantized activation rows in the vec-dot partner format of the
/// weight's kernel (marshalled once per op, shared by every shard).
enum QuantActs {
    /// Q8_0 kernel partner.
    Q8_0(Vec<crate::ggml::q8_0::BlockQ8_0>),
    /// Q3_K kernel partner (Q8_K rows).
    Q8K(Vec<crate::ggml::q8_k::BlockQ8K>),
    /// F16 kernel partner — activations stay f32 (the OP_SML16 kernel
    /// multiplies F16 weights against f32 activations directly, which is
    /// what keeps the lane bit-identical to the host reference).
    F16(Vec<f32>),
}

/// One shard's weight rows, borrowed from the parent tensor (the inline
/// execution path).
enum BlockRows<'a> {
    /// Q8_0 block rows.
    Q8_0(&'a [BlockQ8_0]),
    /// Q3_K super-block rows.
    Q3K(&'a [BlockQ3K]),
    /// F16 element rows (block size 1).
    F16(&'a [F16]),
}

/// The owned (`'static`) form of [`BlockRows`] an enqueued lane job
/// carries: the shard's rows are sliced out of the parent tensor at
/// submit time, so the job outlives the borrowed [`OpDesc`].
enum OwnedBlockRows {
    /// Q8_0 block rows.
    Q8_0(Vec<BlockQ8_0>),
    /// Q3_K super-block rows.
    Q3K(Vec<BlockQ3K>),
    /// F16 element rows.
    F16(Vec<F16>),
}

impl OwnedBlockRows {
    fn as_rows(&self) -> BlockRows<'_> {
        match self {
            OwnedBlockRows::Q8_0(b) => BlockRows::Q8_0(b),
            OwnedBlockRows::Q3K(b) => BlockRows::Q3K(b),
            OwnedBlockRows::F16(b) => BlockRows::F16(b),
        }
    }
}

/// What one shard execution produces: output rows, phase breakdown,
/// residency-cache delta.
type ShardOut = (Vec<f32>, PhaseBreakdown, CacheStats);

/// An in-flight sharded submission: every shard has been enqueued on its
/// lane's FIFO worker (or, without a pool, already executed inline) and
/// parked a [`CompletionSlot`]; [`Coordinator::join_sharded`] waits the
/// slots **in shard order** and stitches/books the results, which keeps
/// outputs and every counter bit-identical to sequential execution no
/// matter how the workers interleave.
pub struct PendingSharded {
    plan: ShardPlan,
    m: usize,
    n: usize,
    k: usize,
    slots: Vec<CompletionSlot<ShardOut>>,
}

impl PendingSharded {
    /// Lane submissions the op decomposed into.
    pub fn shards(&self) -> usize {
        self.plan.len()
    }
}

/// The coordinator: lanes + lane workers + host pool + policy + metrics.
pub struct Coordinator {
    lanes: Vec<Arc<Mutex<LaneSim>>>,
    /// One FIFO worker per lane when parallel shard execution is enabled
    /// (`host_threads > 1`); `None` runs shards inline on the caller.
    pool: Option<LanePool>,
    /// The lane configuration (also the cycle model the shard threshold
    /// derives from).
    imax: ImaxConfig,
    /// Host worker threads (the A72 pair in the paper's setup).
    pub host_threads: usize,
    /// Routing policy.
    pub policy: OffloadPolicy,
    /// Shared counters.
    pub metrics: Arc<CoordinatorMetrics>,
    next_lane: AtomicUsize,
    /// Test/experiment override for [`Coordinator::min_shard_rows`]
    /// (0 = derive from the cycle model).
    min_rows_override: AtomicUsize,
    /// Sticky weight→lane assignment (keyed by [`WeightId`]): the lane
    /// whose LMM cache holds — or will hold — the weight's tiles.
    affinity: Mutex<HashMap<u64, usize>>,
}

impl Coordinator {
    /// Build with `lanes` IMAX lanes and a host pool. With
    /// `host_threads > 1` the coordinator also spawns one worker thread
    /// per lane and sharded submissions execute concurrently across
    /// lanes; `host_threads == 1` is the sequential baseline (identical
    /// outputs and counters, see `DESIGN.md` "Concurrency model").
    pub fn new(imax: ImaxConfig, lanes: usize, host_threads: usize, policy: OffloadPolicy) -> Coordinator {
        Coordinator {
            lanes: (0..lanes)
                .map(|_| {
                    Arc::new(Mutex::ranked(rank::IMAX_LANE, "imax.lane", LaneSim::new(imax.clone())))
                })
                .collect(),
            pool: (host_threads > 1 && lanes > 0).then(|| LanePool::new(lanes)),
            imax,
            host_threads,
            policy,
            metrics: Arc::new(CoordinatorMetrics::default()),
            next_lane: AtomicUsize::new(0),
            min_rows_override: AtomicUsize::new(0),
            affinity: Mutex::ranked(rank::COORD_AFFINITY, "coord.affinity", HashMap::new()),
        }
    }

    /// Whether sharded submissions run on the lane worker pool (true) or
    /// inline on the submitting thread (false).
    pub fn parallel(&self) -> bool {
        self.pool.is_some()
    }

    /// Block until every enqueued lane-worker job has drained — the
    /// graceful-shutdown barrier the server runs after its workers have
    /// finished, so no shard is still executing when the process exits.
    /// A no-op in inline mode (the caller already ran every shard).
    pub fn quiesce(&self) {
        if let Some(pool) = &self.pool {
            pool.wait_idle();
        }
    }

    /// The lane configuration.
    pub fn config(&self) -> &ImaxConfig {
        &self.imax
    }

    /// Number of lanes.
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Per-lane cache budget (lanes are homogeneous; 0 without lanes or
    /// with the cache disabled).
    pub fn lane_cache_budget(&self) -> usize {
        self.lanes
            .first()
            .map(|l| l.lock().lmm.cache_budget())
            .unwrap_or(0)
    }

    /// Per-lane cumulative cost snapshot, in lane order — the
    /// introspection the shard-scaling experiment diffs across steps.
    pub fn lane_costs(&self) -> Vec<LaneCost> {
        self.lanes
            .iter()
            .map(|l| {
                let lane = l.lock();
                LaneCost {
                    cycles: lane.total.total(),
                    loaded_bytes: lane.lmm.loaded_bytes,
                    weight_load_bytes: lane.lmm.loaded_weight_bytes,
                    cache: lane.cache_stats(),
                }
            })
            .collect()
    }

    /// Seed residency from a compiled [`OpPlan`] for **whole-weight**
    /// routing ([`Coordinator::submit_op`]): weights are distributed over
    /// lanes by [`OpPlan::lane_assignment`] — kind-grouped so each lane
    /// serves a single CONF kind where lane count allows, hottest-first
    /// within a kind — and pinned while they fit their lane's cache
    /// budget.
    pub fn apply_plan(&self, plan: &OpPlan) {
        if self.lanes.is_empty() {
            return;
        }
        let mut map = self.affinity.lock();
        let mut remaining: Vec<usize> = self
            .lanes
            .iter()
            .map(|l| l.lock().lmm.cache_budget())
            .collect();
        for (wu, idx) in plan.lane_assignment(self.lanes.len()) {
            if !self.policy.offloads_use(wu.dtype) {
                continue; // e.g. F16 conv weights under the quantized-only policy
            }
            map.insert(wu.wid.0, idx);
            if wu.bytes <= remaining[idx] {
                remaining[idx] -= wu.bytes;
                self.lanes[idx].lock().pin_weight(wu.wid);
            }
        }
    }

    /// Seed residency for **sharded** routing
    /// ([`Coordinator::submit_sharded`]): each offload-eligible weight's
    /// row-tile shards, hottest weight first, are pinned on their owning
    /// lanes while they fit the per-lane budget. The shard geometry (and
    /// the derived shard [`WeightId`]s) is recomputed identically at
    /// execution time, so warm submissions hit exactly what was pinned.
    pub fn apply_plan_sharded(&self, plan: &OpPlan) {
        if self.lanes.is_empty() {
            return;
        }
        let budget = self.lane_cache_budget();
        let mut remaining = vec![budget; self.lanes.len()];
        for wu in plan.weight_uses() {
            if !self.policy.offloads_use(wu.dtype) {
                continue; // this policy executes those sites on the host
            }
            let rows = wu.rows.max(1);
            // The same derivation submit_sharded uses at execution time
            // (`shard_geometry`), so the shard geometry — and the derived
            // shard ids — agree and warm submissions hit what was pinned.
            let Some(kind) = KernelKind::of_dtype(wu.dtype) else {
                continue; // not lane-eligible, never submitted sharded
            };
            let row_bytes = weight_row_bytes(kind, wu.k);
            let sp = self.shard_geometry(kind, Some(wu.wid), rows, wu.k, wu.n);
            for shard in &sp.shards {
                let bytes = shard.len() * row_bytes;
                if let Some(wid) = shard.wid {
                    if bytes <= remaining[shard.lane] {
                        remaining[shard.lane] -= bytes;
                        self.lanes[shard.lane].lock().pin_weight(wid);
                    }
                }
            }
        }
    }

    /// Minimum weight rows one shard must carry to be worth its own lane
    /// submission, derived from the cycle model: a shard pays a fixed
    /// cost of three DMA setups (acts + weights + drain) plus per-PE
    /// REGV/RANGE/CONF setup before any row earns cycles, and one row
    /// earns `n·(beats+2)` EXEC cycles plus its weight-stream and drain
    /// bytes. The threshold requires the per-row work to amortize the
    /// fixed cost 4× over, which keeps the tiny `TimeEmbed` GEMVs
    /// (`n == 1`, small `k`) on a single lane while every matmul with
    /// real activation batches still splits lanes-wide.
    ///
    /// [`Coordinator::set_min_shard_rows`] overrides the derivation
    /// (tests pin sub-threshold geometries with it).
    pub fn min_shard_rows(&self, kind: KernelKind, k: usize, n: usize) -> usize {
        let forced = self.min_rows_override.load(Ordering::Relaxed);
        if forced > 0 {
            return forced;
        }
        let kcfg = KernelConfig::for_kind(kind);
        let pe = kcfg.pe_count() as u64;
        let fixed = 3 * self.imax.dma_setup_cycles
            + (self.imax.regv_cycles_per_pe
                + self.imax.range_cycles_per_pe
                + self.imax.conf_cycles_per_pe)
                * pe;
        let stream = |bytes: u64| (bytes as f64 / self.imax.dma_bytes_per_cycle).ceil() as u64;
        let row_cycles = n as u64 * (kcfg.beats_for_dot(k) + 2)
            + stream(weight_row_bytes(kind, k) as u64)
            + stream(n as u64 * 4);
        ((4 * fixed).div_ceil(row_cycles.max(1))) as usize
    }

    /// Force [`Coordinator::min_shard_rows`] to a fixed value (`0`
    /// restores the cycle-model derivation). Affects the pin pass and
    /// execution identically, so pinned and executed geometries always
    /// agree.
    pub fn set_min_shard_rows(&self, rows: usize) {
        self.min_rows_override.store(rows, Ordering::Relaxed);
    }

    /// The shard geometry of one op — the single derivation shared by
    /// the pin pass ([`Coordinator::apply_plan_sharded`]) and execution
    /// ([`Coordinator::submit_sharded`]): rows capped to the per-lane
    /// cache budget, floored by the cycle-model shard threshold.
    pub fn shard_geometry(
        &self,
        kind: KernelKind,
        wid: Option<WeightId>,
        m: usize,
        k: usize,
        n: usize,
    ) -> ShardPlan {
        let row_bytes = weight_row_bytes(kind, k);
        let cap = ShardPlan::cap_rows(row_bytes, self.lane_cache_budget(), m);
        let min_rows = self.min_shard_rows(kind, k, n);
        ShardPlan::new(m, self.lanes.len(), cap, min_rows, wid)
    }

    /// Pick the lane for an op: follow the weight's affinity when it has
    /// one, assign a sticky lane on first sight, round-robin anonymous
    /// weights.
    fn pick_lane(&self, wid: Option<WeightId>) -> usize {
        let rr = || {
            self.next_lane.fetch_add(1, std::sync::atomic::Ordering::Relaxed) % self.lanes.len()
        };
        match wid {
            Some(id) => {
                let mut map = self.affinity.lock();
                match map.entry(id.0) {
                    std::collections::hash_map::Entry::Occupied(e) => {
                        self.metrics
                            .affinity_hits
                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        *e.get()
                    }
                    std::collections::hash_map::Entry::Vacant(v) => {
                        let idx = rr();
                        v.insert(idx);
                        idx
                    }
                }
            }
            None => rr(),
        }
    }

    /// Marshal the activation rows into the weight kernel's vec-dot
    /// partner format (host-side, once per op): quantized kernels get
    /// their quantized partner rows, the F16 kernel keeps the f32 rows
    /// verbatim (no activation conversion — the bit-identity contract).
    fn marshal_acts(w: &Tensor, x: &Tensor) -> QuantActs {
        match &w.data {
            crate::ggml::tensor::Storage::Q8_0(_) => QuantActs::Q8_0(
                (0..x.rows).flat_map(|r| q8_0::quantize_row(x.row_f32(r))).collect(),
            ),
            crate::ggml::tensor::Storage::Q3K(_) => QuantActs::Q8K(
                (0..x.rows).flat_map(|r| q8_k::quantize_row(x.row_f32(r))).collect(),
            ),
            crate::ggml::tensor::Storage::F16(_) => QuantActs::F16(x.as_f32().to_vec()),
            _ => unreachable!("policy only offloads lane-eligible weights"),
        }
    }

    /// The lane kernel a lane-eligible weight selects.
    fn kernel_kind(w: &Tensor) -> KernelKind {
        KernelKind::of_dtype(w.dtype()).expect("policy only offloads lane-eligible weights")
    }

    /// Whether an op is eligible for (sharded) lane submission: the
    /// single gate [`crate::sd::backend::ShardedBackend`] and the
    /// serving rendezvous share. Kind-aware: F16 weights shard only for
    /// conv sites (and only under the conv-offload policy).
    pub fn shardable(&self, op: &OpDesc<'_>) -> bool {
        self.policy.offloads_op(op.w, op.kind) && !self.lanes.is_empty()
    }

    /// Borrow weight rows `rows` of `w` as kernel block rows.
    fn borrow_rows(w: &Tensor, rows: Range<usize>) -> BlockRows<'_> {
        match &w.data {
            crate::ggml::tensor::Storage::Q8_0(blocks) => {
                let bpr = w.cols / QK8_0;
                BlockRows::Q8_0(&blocks[rows.start * bpr..rows.end * bpr])
            }
            crate::ggml::tensor::Storage::Q3K(blocks) => {
                let bpr = w.cols / QK_K;
                BlockRows::Q3K(&blocks[rows.start * bpr..rows.end * bpr])
            }
            crate::ggml::tensor::Storage::F16(halves) => {
                BlockRows::F16(&halves[rows.start * w.cols..rows.end * w.cols])
            }
            _ => unreachable!("policy only offloads lane-eligible weights"),
        }
    }

    /// Clone weight rows `rows` of `w` into an owned job payload (the
    /// enqueued form; a shard's rows only, never the whole matrix).
    fn clone_rows(w: &Tensor, rows: Range<usize>) -> OwnedBlockRows {
        match Self::borrow_rows(w, rows) {
            BlockRows::Q8_0(b) => OwnedBlockRows::Q8_0(b.to_vec()),
            BlockRows::Q3K(b) => OwnedBlockRows::Q3K(b.to_vec()),
            BlockRows::F16(b) => OwnedBlockRows::F16(b.to_vec()),
        }
    }

    /// Run weight rows `rows` of `w` against pre-marshalled activations
    /// on lane `lane_idx`, caching under `wid`. The single lane-call
    /// primitive every *inline* submission path uses (the worker path
    /// calls [`exec_rows`] with owned rows instead — same core, same
    /// accounting). Returns the `[n, rows.len()]` output rows, the phase
    /// breakdown and the cache delta.
    fn run_rows_on_lane(
        &self,
        lane_idx: usize,
        w: &Tensor,
        rows: Range<usize>,
        wid: Option<WeightId>,
        acts: &QuantActs,
        charge_act_bytes: bool,
    ) -> ShardOut {
        let m_i = rows.end - rows.start;
        exec_rows(
            &self.lanes[lane_idx],
            wid,
            Self::borrow_rows(w, rows),
            m_i,
            w.cols,
            acts,
            charge_act_bytes,
        )
    }

    /// Submit one typed op, routing by policy: offload-eligible weights
    /// run whole on one residency-selected lane, everything else runs on
    /// the host pool. This is the submission path that replaced the
    /// eager `execute_ref`/`execute_batch` entry points (counter
    /// semantics preserved: one `record_offload`/`record_host` per op).
    pub fn submit_op(&self, op: &OpDesc<'_>) -> Tensor {
        if self.policy.offloads_op(op.w, op.kind) && !self.lanes.is_empty() {
            let (w, x) = (op.w, op.x);
            let (m, n) = (w.rows, x.rows);
            let acts = Self::marshal_acts(w, x);
            // OpDesc.wid is the weight identity everywhere (the
            // constructors default it to the tensor's own id).
            let idx = self.pick_lane(op.wid);
            let (data, bd, delta) = self.run_rows_on_lane(idx, w, 0..m, op.wid, &acts, true);
            self.metrics.record_cache(delta);
            self.metrics.record_offload(op.macs(), bd.total());
            Tensor::f32(n, m, data)
        } else {
            self.metrics.record_host(op.macs());
            ggml::mul_mat(op.w, op.x, self.host_threads)
        }
    }

    /// Submit one offload-eligible op **sharded across every lane**: the
    /// weight's row-tiles are partitioned by [`ShardPlan`] (balanced,
    /// capped to the per-lane cache budget so each shard is cacheable),
    /// each shard executes on its lane under a derived shard
    /// [`WeightId`], and the outputs are stitched column-wise.
    ///
    /// Stitching invariant: output element `[a, j]` is the vec-dot of
    /// weight row `j` with activation row `a`, computed by exactly one
    /// shard from the same operand bytes the unsharded kernel would
    /// consume — so the stitched tensor is **bit-identical** to
    /// [`Coordinator::submit_op`]'s for every lane count.
    pub fn submit_sharded(&self, op: &OpDesc<'_>) -> ShardedRun {
        self.join_sharded(self.start_sharded(op))
    }

    /// Fan one op's shards out to their lanes and **return immediately**
    /// with a [`PendingSharded`] ticket — the asynchronous half of
    /// [`Coordinator::submit_sharded`] that
    /// [`crate::sd::backend::ShardedBackend::submit`] maps an
    /// [`crate::sd::backend::OpHandle`] onto.
    ///
    /// The submitting thread does the order-sensitive work while the op
    /// is still in program order: marshal the activations once (shared by
    /// every shard via an `Arc`), derive the shard geometry, and enqueue
    /// each shard on its owning lane's FIFO worker. Because each lane
    /// executes its queue serially in enqueue order, every lane's
    /// `LaneSim` state (cache LRU, CONF history, cycle/byte counters)
    /// evolves exactly as under sequential execution — parallelism only
    /// overlaps *different* lanes. Without a pool (`host_threads <= 1`)
    /// the shards run inline here and the ticket is already complete.
    ///
    /// Activation broadcast elision: all shards stream identical
    /// activation tiles, so only shard 0 charges the op's activation
    /// bytes; the other shards run with
    /// [`LaneSim::set_act_byte_elision`] — per-lane *byte* ledgers stop
    /// scaling with the lane count while cycles stay untouched.
    pub fn start_sharded(&self, op: &OpDesc<'_>) -> PendingSharded {
        assert!(
            self.shardable(op),
            "start_sharded wants an offload-eligible op and at least one lane"
        );
        let (w, x) = (op.w, op.x);
        let (m, n, k) = (w.rows, x.rows, w.cols);
        let plan = self.shard_geometry(Self::kernel_kind(w), op.wid, m, k, n);
        let acts = Arc::new(Self::marshal_acts(w, x));
        let mut slots = Vec::with_capacity(plan.len());
        for (i, shard) in plan.shards.iter().enumerate() {
            let slot = CompletionSlot::new();
            let charge_act_bytes = i == 0;
            match &self.pool {
                Some(pool) => {
                    let lane = Arc::clone(&self.lanes[shard.lane]);
                    let rows = Self::clone_rows(w, shard.rows.clone());
                    let acts = Arc::clone(&acts);
                    let (wid, m_i) = (shard.wid, shard.len());
                    let fill = slot.clone();
                    pool.submit_to(shard.lane, move || {
                        fill.fill(exec_rows(
                            &lane,
                            wid,
                            rows.as_rows(),
                            m_i,
                            k,
                            &acts,
                            charge_act_bytes,
                        ));
                    });
                }
                None => slot.fill(self.run_rows_on_lane(
                    shard.lane,
                    w,
                    shard.rows.clone(),
                    shard.wid,
                    &acts,
                    charge_act_bytes,
                )),
            }
            slots.push(slot);
        }
        PendingSharded { plan, m, n, k, slots }
    }

    /// Block until every shard of `pending` completes, stitch the
    /// outputs column-wise and book the metrics — the synchronous half
    /// of [`Coordinator::submit_sharded`].
    ///
    /// Slots are waited **in shard order** and every counter
    /// (`record_offload`, `record_cache`, `record_sharded`, the summed
    /// phase/cache deltas) is merged on the joining thread in that same
    /// order, so `CoordinatorMetrics` and the returned [`ShardedRun`]
    /// are bit-identical to the sequential path regardless of how the
    /// lane workers interleaved in wall-clock time.
    pub fn join_sharded(&self, pending: PendingSharded) -> ShardedRun {
        let PendingSharded { plan, m, n, k, slots } = pending;
        let mut out = vec![0.0f32; n * m];
        let mut phases = PhaseBreakdown::default();
        let mut cache = CacheStats::default();
        for (shard, slot) in plan.shards.iter().zip(slots) {
            let m_i = shard.len();
            let (data, bd, delta) = slot.wait();
            for a in 0..n {
                out[a * m + shard.rows.start..a * m + shard.rows.end]
                    .copy_from_slice(&data[a * m_i..(a + 1) * m_i]);
            }
            self.metrics.record_offload((m_i * k * n) as u64, bd.total());
            self.metrics.record_cache(delta);
            phases += bd;
            cache += delta;
        }
        self.metrics.record_sharded(plan.len() as u64);
        ShardedRun { out: Tensor::f32(n, m, out), phases, cache, shards: plan.len() }
    }

    /// Execute one owned job synchronously through the submission path.
    pub fn execute(&self, job: &MatMulJob) -> Tensor {
        self.submit_op(&job.as_op())
    }

    /// Execute a batch with shape-keyed coalescing: lane-eligible jobs
    /// sharing the *same weight tensor* (same `Arc`) are merged into one
    /// submission whose activation rows are the concatenation of the
    /// members' rows, and merged groups are ordered by kernel kind to
    /// avoid CONF switches. Outputs are returned per job, in submission
    /// order, **bit-identical** to executing each job alone (each output
    /// row is an independent vec-dot of the same operands).
    pub fn execute_coalesced(&self, jobs: &[MatMulJob]) -> Vec<Tensor> {
        let mut out: Vec<Option<Tensor>> = (0..jobs.len()).map(|_| None).collect();
        // Group lane jobs by weight identity; host jobs run individually.
        let mut host_jobs: Vec<usize> = Vec::new();
        let mut groups: Vec<Vec<usize>> = Vec::new();
        let mut by_weight: HashMap<usize, usize> = HashMap::new();
        for (i, job) in jobs.iter().enumerate() {
            if self.policy.offloads_op(&job.w, job.kind) && !self.lanes.is_empty() {
                let key = Arc::as_ptr(&job.w) as usize;
                match by_weight.entry(key) {
                    std::collections::hash_map::Entry::Occupied(e) => groups[*e.get()].push(i),
                    std::collections::hash_map::Entry::Vacant(v) => {
                        v.insert(groups.len());
                        groups.push(vec![i]);
                    }
                }
            } else {
                host_jobs.push(i);
            }
        }
        // Order merged groups by shape key: same-kernel (and, within a
        // kernel, same-geometry) groups submit back-to-back, so a lane
        // re-hit by consecutive submissions avoids CONF reconfiguration.
        groups.sort_by_key(|members| {
            let key = jobs[members[0]].shape_key();
            (key.dtype.name(), key.m, key.k)
        });

        for members in &groups {
            let job0 = &jobs[members[0]];
            let w = &job0.w;
            if members.len() == 1 {
                let i = members[0];
                out[i] = Some(self.lane_mul(w, &jobs[i].x));
                continue;
            }
            // Concatenate activation rows across the member jobs.
            let k = w.cols;
            let total_rows: usize = members.iter().map(|&i| jobs[i].x.rows).sum();
            let mut data = Vec::with_capacity(total_rows * k);
            for &i in members {
                assert_eq!(jobs[i].x.cols, k, "coalesced jobs must share K");
                data.extend_from_slice(jobs[i].x.as_f32());
            }
            let x_cat = Tensor::f32(total_rows, k, data);
            let y = self.lane_mul(w, &x_cat); // [total_rows, m]
            self.metrics.record_batch(members.len() as u64);
            // Split the stacked output rows back per job.
            let m = w.rows;
            let mut row = 0;
            for &i in members {
                let n_i = jobs[i].x.rows;
                let slice = &y.as_f32()[row * m..(row + n_i) * m];
                out[i] = Some(Tensor::f32(n_i, m, slice.to_vec()));
                row += n_i;
            }
        }
        for &i in &host_jobs {
            self.metrics.record_host(jobs[i].macs());
            out[i] = Some(ggml::mul_mat(&jobs[i].w, &jobs[i].x, self.host_threads));
        }
        out.into_iter().map(|t| t.expect("all jobs executed")).collect()
    }

    /// One whole-op lane execution (the coalesced path's primitive):
    /// marshal, pick the residency lane, run all rows, book metrics.
    fn lane_mul(&self, w: &Tensor, x: &Tensor) -> Tensor {
        let (m, n, k) = (w.rows, x.rows, w.cols);
        let acts = Self::marshal_acts(w, x);
        let idx = self.pick_lane(w.wid);
        let (data, bd, delta) = self.run_rows_on_lane(idx, w, 0..m, w.wid, &acts, true);
        self.metrics.record_cache(delta);
        self.metrics.record_offload((m * k * n) as u64, bd.total());
        Tensor::f32(n, m, data)
    }
}

/// Execute one shard's weight rows against the marshalled activations on
/// `lane`, holding its lock for the duration — the kernel-dispatch core
/// both the inline path ([`Coordinator::submit_op`] and pool-less
/// shards) and the lane workers share, so phase and cache accounting are
/// identical no matter which thread runs the shard.
/// `charge_act_bytes == false` applies activation broadcast elision for
/// the shard's duration (see [`LaneSim::set_act_byte_elision`]).
fn exec_rows(
    lane: &Mutex<LaneSim>,
    wid: Option<WeightId>,
    rows: BlockRows<'_>,
    m_i: usize,
    k: usize,
    acts: &QuantActs,
    charge_act_bytes: bool,
) -> ShardOut {
    let mut lane = lane.lock();
    let before = lane.cache_stats();
    lane.set_act_byte_elision(!charge_act_bytes);
    let (data, bd) = match (rows, acts) {
        (BlockRows::Q8_0(blocks), QuantActs::Q8_0(a)) => {
            let bpr = k / QK8_0;
            lane.mul_mat_q8_0_cached(wid, blocks, m_i, a, a.len() / bpr, k)
                .expect("job shapes fit LMM")
        }
        (BlockRows::Q3K(blocks), QuantActs::Q8K(a)) => {
            let bpr = k / QK_K;
            lane.mul_mat_q3_k_cached(wid, blocks, m_i, a, a.len() / bpr, k)
                .expect("job shapes fit LMM")
        }
        (BlockRows::F16(halves), QuantActs::F16(a)) => lane
            .mul_mat_f16_cached(wid, halves, m_i, a, a.len() / k, k)
            .expect("job shapes fit LMM"),
        _ => unreachable!("marshalled activations match the weight kernel"),
    };
    lane.set_act_byte_elision(false);
    let delta = lane.cache_stats() - before;
    (data, bd, delta)
}

/// Helper: build a quantized [`OpKind::Linear`] job from f32 weights.
pub fn make_job(name: &str, w_f32: Tensor, dtype: DType, x: Tensor) -> MatMulJob {
    let w = match dtype {
        DType::F32 => w_f32,
        _ => w_f32.quantize(dtype),
    };
    MatMulJob { name: name.to_string(), kind: OpKind::Linear, w: Arc::new(w), x: Arc::new(x) }
}

// Re-exports used in tests and examples.
pub use crate::ggml::tensor::Storage;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ggml::q3_k;
    use crate::util::rng::Xoshiro256pp;

    fn rnd(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut r = Xoshiro256pp::seed_from_u64(seed);
        let mut v = vec![0.0f32; rows * cols];
        r.fill_normal(&mut v, 0.5);
        Tensor::f32(rows, cols, v)
    }

    fn coordinator(lanes: usize) -> Coordinator {
        Coordinator::new(ImaxConfig::fpga(1), lanes, 2, OffloadPolicy::QuantizedOnly)
    }

    #[test]
    fn routes_by_policy_and_counts() {
        let c = coordinator(2);
        let jq = make_job("q", rnd(4, 64, 1), DType::Q8_0, rnd(3, 64, 2));
        let jf = make_job("f", rnd(4, 64, 3), DType::F16, rnd(3, 64, 4));
        c.execute(&jq);
        c.execute(&jf);
        assert_eq!(c.metrics.offloaded_jobs.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(c.metrics.host_jobs.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert!(c.metrics.offload_ratio() > 0.0);
    }

    #[test]
    fn coordinator_matches_direct_ggml_q8_0() {
        let c = coordinator(3);
        let w = rnd(6, 128, 5);
        let x = rnd(4, 128, 6);
        let job = make_job("m", w.clone(), DType::Q8_0, x.clone());
        let got = c.execute(&job);
        let want = ggml::mul_mat(&w.quantize(DType::Q8_0), &x, 1);
        for (a, b) in got.as_f32().iter().zip(want.as_f32()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn submitted_jobs_preserve_order_and_use_all_lanes() {
        let c = coordinator(4);
        let jobs: Vec<_> = (0..12)
            .map(|i| make_job(&format!("j{i}"), rnd(2, 64, 10 + i), DType::Q8_0, rnd(2, 64, 50 + i)))
            .collect();
        let outs: Vec<Tensor> = jobs.iter().map(|j| c.execute(j)).collect();
        assert_eq!(outs.len(), 12);
        // Verify each against direct computation (order preserved).
        for (job, out) in jobs.iter().zip(&outs) {
            let want = ggml::mul_mat(&job.w, &job.x, 1);
            assert_eq!(out.as_f32(), want.as_f32());
        }
        assert_eq!(
            c.metrics.offloaded_jobs.load(std::sync::atomic::Ordering::Relaxed),
            12
        );
        // Anonymous weights round-robin: every lane did real work.
        let costs = c.lane_costs();
        assert_eq!(costs.len(), 4);
        assert!(costs.iter().all(|lc| lc.cycles > 0), "round-robin must hit every lane");
    }

    #[test]
    fn host_only_policy_never_offloads() {
        let c = Coordinator::new(ImaxConfig::fpga(1), 2, 2, OffloadPolicy::HostOnly);
        let job = make_job("q", rnd(2, 64, 7), DType::Q8_0, rnd(2, 64, 8));
        c.execute(&job);
        assert_eq!(c.metrics.offloaded_jobs.load(std::sync::atomic::Ordering::Relaxed), 0);
    }

    #[test]
    fn q3k_jobs_route_and_compute() {
        let c = coordinator(1);
        let w = rnd(3, 256, 9);
        let x = rnd(2, 256, 10);
        let job = make_job("q3", w.clone(), DType::Q3K, x.clone());
        let got = c.execute(&job);
        // Lane computes the imax5 (5-bit scale) variant.
        let wq = w.quantize(DType::Q3K);
        let blocks = match &wq.data {
            Storage::Q3K(b) => b.clone(),
            _ => unreachable!(),
        };
        let acts: Vec<_> = (0..2).flat_map(|r| q8_k::quantize_row(x.row_f32(r))).collect();
        for a_row in 0..2 {
            for w_row in 0..3 {
                let want = q3_k::vec_dot_imax5(&blocks[w_row..w_row + 1], &acts[a_row..a_row + 1]);
                assert_eq!(got.as_f32()[a_row * 3 + w_row].to_bits(), want.to_bits());
            }
        }
    }

    #[test]
    fn shape_key_groups_same_geometry() {
        let a = make_job("a", rnd(4, 64, 1), DType::Q8_0, rnd(3, 64, 2));
        let b = make_job("b", rnd(4, 64, 3), DType::Q8_0, rnd(7, 64, 4));
        let c = make_job("c", rnd(8, 64, 5), DType::Q8_0, rnd(3, 64, 6));
        assert_eq!(a.shape_key(), b.shape_key(), "N does not enter the key");
        assert_ne!(a.shape_key(), c.shape_key(), "M does");
        assert_eq!(a.shape_key(), ShapeKey { dtype: DType::Q8_0, m: 4, k: 64 });
    }

    #[test]
    fn coalesced_bit_identical_to_serial() {
        // Three requests hitting the same two weight tensors, plus one
        // host (F16) job: coalesced outputs must match per-job execution
        // bit-for-bit, in submission order.
        let w1 = Arc::new(rnd(6, 128, 1).quantize(DType::Q8_0));
        let w2 = Arc::new(rnd(4, 256, 2).quantize(DType::Q3K));
        let wf = Arc::new(rnd(5, 64, 3).quantize(DType::F16));
        let mut jobs = Vec::new();
        for r in 0..3u64 {
            jobs.push(MatMulJob {
                name: format!("r{r}.l1"),
                kind: OpKind::Linear,
                w: Arc::clone(&w1),
                x: Arc::new(rnd(2 + r as usize, 128, 10 + r)),
            });
            jobs.push(MatMulJob {
                name: format!("r{r}.l2"),
                kind: OpKind::Linear,
                w: Arc::clone(&w2),
                x: Arc::new(rnd(3, 256, 20 + r)),
            });
        }
        jobs.push(MatMulJob {
            name: "host".into(),
            kind: OpKind::Linear,
            w: wf,
            x: Arc::new(rnd(2, 64, 30)),
        });

        let serial = coordinator(2);
        let want: Vec<Tensor> = jobs.iter().map(|j| serial.execute(j)).collect();
        let batched = coordinator(2);
        let got = batched.execute_coalesced(&jobs);
        assert_eq!(got.len(), want.len());
        for (g, w_) in got.iter().zip(&want) {
            assert_eq!((g.rows, g.cols), (w_.rows, w_.cols));
            for (a, b) in g.as_f32().iter().zip(w_.as_f32()) {
                assert_eq!(a.to_bits(), b.to_bits(), "batched == serial bit-exact");
            }
        }
    }

    #[test]
    fn coalescing_merges_submissions_and_saves_cycles() {
        let w = Arc::new(rnd(8, 128, 1).quantize(DType::Q8_0));
        let jobs: Vec<MatMulJob> = (0..6u64)
            .map(|r| MatMulJob {
                name: format!("r{r}"),
                kind: OpKind::Linear,
                w: Arc::clone(&w),
                x: Arc::new(rnd(4, 128, 40 + r)),
            })
            .collect();

        let serial = coordinator(1);
        for j in &jobs {
            serial.execute(j);
        }
        let batched = coordinator(1);
        batched.execute_coalesced(&jobs);

        let ord = std::sync::atomic::Ordering::Relaxed;
        assert_eq!(serial.metrics.offloaded_jobs.load(ord), 6);
        assert_eq!(batched.metrics.offloaded_jobs.load(ord), 1, "one merged submission");
        assert_eq!(batched.metrics.batched_submissions.load(ord), 1);
        assert_eq!(batched.metrics.coalesced_jobs.load(ord), 6);
        assert_eq!(
            serial.metrics.offloaded_macs.load(ord),
            batched.metrics.offloaded_macs.load(ord),
            "same work either way"
        );
        assert!(
            batched.metrics.imax_cycles.load(ord) < serial.metrics.imax_cycles.load(ord),
            "batched submission amortizes DMA setup + weight streaming: {} vs {}",
            batched.metrics.imax_cycles.load(ord),
            serial.metrics.imax_cycles.load(ord)
        );
    }

    #[test]
    fn residency_affinity_routes_weight_to_one_lane_and_reuses_cache() {
        let c = coordinator(3);
        let w = Arc::new(
            rnd(6, 128, 40).quantize(DType::Q8_0).with_wid(crate::ggml::WeightId(77)),
        );
        let ord = std::sync::atomic::Ordering::Relaxed;
        for i in 0..4u64 {
            let job = MatMulJob {
                name: format!("j{i}"),
                kind: OpKind::Linear,
                w: Arc::clone(&w),
                x: Arc::new(rnd(2, 128, 60 + i)),
            };
            let got = c.execute(&job);
            let want = ggml::mul_mat(&w, &job.x, 1);
            for (a, b) in got.as_f32().iter().zip(want.as_f32()) {
                assert_eq!(a.to_bits(), b.to_bits(), "cached execution stays bit-exact");
            }
        }
        assert_eq!(c.metrics.affinity_hits.load(ord), 3, "first call assigns, rest follow");
        assert_eq!(c.metrics.cache_misses.load(ord), 1, "one cold fill");
        assert_eq!(c.metrics.cache_hits.load(ord), 3, "later jobs find the weight resident");
        assert!(c.metrics.cache_hit_bytes.load(ord) > 0);
        assert!((c.metrics.cache_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn apply_plan_preassigns_affinity_before_first_execution() {
        use crate::sd::plan::{OpPlan, OpSite};
        let c = coordinator(2);
        let site = |seq: usize, wid: u64, bytes: usize| OpSite {
            seq,
            kind: OpKind::Linear,
            wid: Some(crate::ggml::WeightId(wid)),
            dtype: DType::Q8_0,
            m: 4,
            k: 128,
            n: 2,
            weight_bytes: bytes,
        };
        let plan = OpPlan { sites: vec![site(0, 1, 4 * 136), site(1, 2, 4 * 136)] };
        c.apply_plan(&plan);
        let ord = std::sync::atomic::Ordering::Relaxed;
        let w = Arc::new(
            rnd(4, 128, 50).quantize(DType::Q8_0).with_wid(crate::ggml::WeightId(1)),
        );
        let job = MatMulJob { name: "a".into(), kind: OpKind::Linear, w, x: Arc::new(rnd(2, 128, 51)) };
        c.execute(&job);
        assert_eq!(
            c.metrics.affinity_hits.load(ord),
            1,
            "the plan pre-assigned this weight's lane"
        );
        c.execute(&job);
        assert_eq!(c.metrics.cache_hits.load(ord), 1, "second call hits the pinned resident");
    }

    #[test]
    fn coalesced_handles_empty_and_singleton() {
        let c = coordinator(2);
        assert!(c.execute_coalesced(&[]).is_empty());
        let job = make_job("solo", rnd(4, 64, 1), DType::Q8_0, rnd(2, 64, 2));
        let got = c.execute_coalesced(std::slice::from_ref(&job));
        let want = c.execute(&job);
        assert_eq!(got[0].as_f32(), want.as_f32());
    }

    #[test]
    fn sharded_submission_bit_identical_and_counts_shards() {
        for (dtype, k) in [(DType::Q8_0, 128), (DType::Q3K, 256)] {
            let w = rnd(11, k, 70).quantize(dtype).with_wid(WeightId(123));
            let x = rnd(3, k, 71);
            let serial = coordinator(1);
            let want = serial.submit_op(&OpDesc::linear(&w, &x));
            for lanes in [1usize, 2, 4] {
                let c = coordinator(lanes);
                // 11 rows sit below the cycle-model threshold; force the
                // lanes-way split to pin the multi-shard geometry.
                c.set_min_shard_rows(1);
                let run = c.submit_sharded(&OpDesc::linear(&w, &x));
                assert_eq!(run.shards, lanes.min(11));
                assert_eq!((run.out.rows, run.out.cols), (3, 11));
                for (a, b) in run.out.as_f32().iter().zip(want.as_f32()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{dtype:?} x{lanes} bit-exact");
                }
                let ord = std::sync::atomic::Ordering::Relaxed;
                assert_eq!(c.metrics.sharded_ops.load(ord), 1);
                assert_eq!(c.metrics.shard_submissions.load(ord), run.shards as u64);
                assert_eq!(c.metrics.offloaded_jobs.load(ord), run.shards as u64);
                assert_eq!(
                    c.metrics.offloaded_macs.load(ord),
                    (11 * k * 3) as u64,
                    "shard MACs sum to the op's MACs"
                );
            }
        }
    }

    #[test]
    fn sharded_warm_step_streams_less_per_lane_as_lanes_grow() {
        // One big weight whose bytes exceed a single lane's cache budget:
        // with more lanes each lane owns fewer shards, the sharded pin
        // pass keeps more of the weight resident in aggregate, and the
        // warm-step weight miss volume drops — the cache acting as a
        // bandwidth-scaling lever.
        use crate::sd::plan::{OpPlan, OpSite};
        let mut imax = ImaxConfig::fpga(1);
        imax.lmm_bytes = 64 << 10;
        imax.weight_cache_bytes = 8 << 10; // 8 KiB per lane
        let w = rnd(128, 512, 80).quantize(DType::Q8_0).with_wid(WeightId(9)); // 68 KiB
        let x = rnd(2, 512, 81);
        let plan = OpPlan {
            sites: vec![OpSite {
                seq: 0,
                kind: OpKind::Linear,
                wid: Some(WeightId(9)),
                dtype: DType::Q8_0,
                m: 128,
                k: 512,
                n: 2,
                weight_bytes: w.byte_size(),
            }],
        };
        let mut warm_by_lanes = Vec::new();
        for lanes in [1usize, 2, 4, 8] {
            let c = Coordinator::new(imax.clone(), lanes, 2, OffloadPolicy::QuantizedOnly);
            c.apply_plan_sharded(&plan);
            c.submit_sharded(&OpDesc::linear(&w, &x)); // cold
            let ord = std::sync::atomic::Ordering::Relaxed;
            let miss0 = c.metrics.cache_miss_bytes.load(ord);
            let hit0 = c.metrics.cache_hit_bytes.load(ord);
            c.submit_sharded(&OpDesc::linear(&w, &x)); // warm
            let warm_miss = c.metrics.cache_miss_bytes.load(ord) - miss0;
            let warm_hit = c.metrics.cache_hit_bytes.load(ord) - hit0;
            warm_by_lanes.push((lanes, warm_miss, warm_hit));
        }
        for pair in warm_by_lanes.windows(2) {
            let ((l0, miss0, hit0), (l1, miss1, hit1)) = (pair[0], pair[1]);
            assert!(
                miss1 < miss0,
                "warm miss bytes must shrink with lanes: {l0} lanes {miss0} B vs {l1} lanes {miss1} B"
            );
            assert!(hit1 >= hit0, "resident bytes grow with lanes: {hit0} vs {hit1}");
        }
    }

    #[test]
    fn apply_plan_sharded_prepins_shards_for_warm_first_step() {
        use crate::sd::plan::{OpPlan, OpSite};
        let w = rnd(32, 128, 90).quantize(DType::Q8_0).with_wid(WeightId(5));
        let x = rnd(2, 128, 91);
        let plan = OpPlan {
            sites: vec![OpSite {
                seq: 0,
                kind: OpKind::Linear,
                wid: Some(WeightId(5)),
                dtype: DType::Q8_0,
                m: 32,
                k: 128,
                n: 2,
                weight_bytes: w.byte_size(),
            }],
        };
        let c = coordinator(2);
        // Sub-threshold rows: force the 2-way split so the pin pass and
        // execution both derive two shards.
        c.set_min_shard_rows(1);
        c.apply_plan_sharded(&plan);
        c.submit_sharded(&OpDesc::linear(&w, &x));
        c.submit_sharded(&OpDesc::linear(&w, &x));
        let ord = std::sync::atomic::Ordering::Relaxed;
        assert_eq!(c.metrics.cache_hits.load(ord), 2, "warm shards hit the pre-pinned ids");
        assert_eq!(c.metrics.cache_insert_failures.load(ord), 0);
    }

    #[test]
    fn tiny_time_embed_gemv_stays_single_lane() {
        // The satellite fix: a TimeEmbed GEMV (n = 1, k = 64) earns so
        // few cycles per row that splitting it lanes-wide saves nothing —
        // the cycle-model threshold keeps it whole on one lane.
        let c = coordinator(8);
        let w = rnd(256, 64, 110).quantize(DType::Q8_0).with_wid(WeightId(21));
        let x = rnd(1, 64, 111);
        let run = c.submit_sharded(&OpDesc::time_embed(&w, &x));
        assert_eq!(run.shards, 1, "tiny GEMV must not split lanes-wide");
        // A real matmul with an activation batch still splits over every
        // lane under the same automatic threshold.
        let wb = rnd(256, 256, 112).quantize(DType::Q8_0).with_wid(WeightId(22));
        let xb = rnd(64, 256, 113);
        let run = c.submit_sharded(&OpDesc::linear(&wb, &xb));
        assert_eq!(run.shards, 8, "batched matmul splits lanes-wide");
        // The threshold itself: GEMV rows are below it, batched ops far above.
        assert!(c.min_shard_rows(KernelKind::Q8_0, 64, 1) > 256 / 2);
        assert!(c.min_shard_rows(KernelKind::Q8_0, 256, 64) <= 32);
    }

    #[test]
    fn worker_pool_matches_inline_execution_bit_and_counter_exact() {
        // The determinism contract: host_threads > 1 executes shards on
        // the lane worker pool, host_threads == 1 runs them inline —
        // outputs, metrics and per-lane cycle/byte counters must agree
        // bit-for-bit.
        let mk = |threads| {
            let c = Coordinator::new(ImaxConfig::fpga(1), 4, threads, OffloadPolicy::QuantizedOnly);
            c.set_min_shard_rows(1);
            c
        };
        let seq = mk(1);
        let par = mk(2);
        assert!(!seq.parallel() && par.parallel());
        let w1 = rnd(64, 128, 120).quantize(DType::Q8_0).with_wid(WeightId(31));
        let w2 = rnd(48, 256, 121).quantize(DType::Q3K).with_wid(WeightId(32));
        for step in 0..3u64 {
            let x1 = rnd(3, 128, 130 + step);
            let x2 = rnd(2, 256, 140 + step);
            for op in [OpDesc::linear(&w1, &x1), OpDesc::linear(&w2, &x2)] {
                let a = seq.submit_sharded(&op);
                let b = par.submit_sharded(&op);
                assert_eq!(a.shards, b.shards);
                assert_eq!(a.phases, b.phases, "summed phases agree");
                for (p, q) in a.out.as_f32().iter().zip(b.out.as_f32()) {
                    assert_eq!(p.to_bits(), q.to_bits(), "stitched bits agree");
                }
            }
        }
        assert_eq!(seq.metrics.snapshot(), par.metrics.snapshot(), "metrics agree");
        for (a, b) in seq.lane_costs().iter().zip(par.lane_costs()) {
            assert_eq!(a.cycles, b.cycles, "per-lane cycles agree");
            assert_eq!(a.loaded_bytes, b.loaded_bytes, "per-lane bytes agree");
            assert_eq!(a.weight_load_bytes, b.weight_load_bytes);
            assert_eq!(a.cache, b.cache);
        }
    }
}
