//! Multi-lane job scheduler.
//!
//! Jobs arrive in submission order; quantized mat-muls round-robin over
//! the configured IMAX lanes (each lane owned by one worker thread),
//! host jobs run on a bounded host pool sized like the A72 (2 cores).
//! Because the host workers also perform the marshalling (activation
//! quantization) for lane jobs, configuring more lanes than
//! `host_threads` ceases to help — the §V-A saturation, observable in
//! this scheduler's metrics.
//!
//! Beyond per-job execution the coordinator supports **batched
//! submission** ([`Coordinator::execute_coalesced`]): jobs that share a
//! weight tensor (same `Arc`) have their activation rows concatenated
//! into one lane submission, which amortizes the per-descriptor DMA
//! setup, the weight-tile streaming, and the CONF/REGV/RANGE phases
//! across requests — the serving layer in [`crate::serve`] is built on
//! this. Groups are ordered by kernel kind so consecutive submissions
//! avoid CONF reconfiguration, the shape-level analog of SD-Acc-style
//! kernel scheduling.
//!
//! Lane selection is **residency-aware**: a job whose weight carries a
//! [`WeightId`] is routed to the lane that already holds (or was
//! assigned) that weight's cached tiles, so cross-step and cross-request
//! reuse land where the bytes are; anonymous weights round-robin as
//! before. [`Coordinator::apply_plan`] seeds the weight→lane map from a
//! compiled [`OpPlan`], sharding the hottest weights across lanes and
//! pinning each lane's share into its LMM cache partition.

use super::metrics::CoordinatorMetrics;
use super::offload::OffloadPolicy;
use crate::ggml::{self, q8_0, q8_k, DType, Tensor, WeightId};
use crate::imax::lane::LaneSim;
use crate::imax::ImaxConfig;
use crate::sd::plan::OpPlan;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// One mat-mul job: quantized weights × f32 activations.
#[derive(Debug, Clone)]
pub struct MatMulJob {
    /// Job label (layer name).
    pub name: String,
    /// Weight tensor.
    pub w: Arc<Tensor>,
    /// Activation tensor `[n, k]` f32.
    pub x: Arc<Tensor>,
}

/// Key identifying lane-batchable job shapes: jobs with equal keys run
/// the same kernel over the same weight geometry, so their lane
/// submissions can share a configuration — [`Coordinator::execute_coalesced`]
/// orders merged groups by this key (and merges jobs whose weight tensor
/// is additionally *identical* into a single batched submission).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ShapeKey {
    /// Weight dtype (selects the lane kernel).
    pub dtype: DType,
    /// Weight rows (output features).
    pub m: usize,
    /// Contraction length.
    pub k: usize,
}

impl MatMulJob {
    /// MAC count.
    pub fn macs(&self) -> u64 {
        (self.w.rows * self.w.cols * self.x.rows) as u64
    }

    /// Shape key for coalescing.
    pub fn shape_key(&self) -> ShapeKey {
        ShapeKey { dtype: self.w.dtype(), m: self.w.rows, k: self.w.cols }
    }
}

/// The coordinator: lanes + host pool + policy + metrics.
pub struct Coordinator {
    lanes: Vec<Mutex<LaneSim>>,
    /// Host worker threads (the A72 pair in the paper's setup).
    pub host_threads: usize,
    /// Routing policy.
    pub policy: OffloadPolicy,
    /// Shared counters.
    pub metrics: Arc<CoordinatorMetrics>,
    next_lane: std::sync::atomic::AtomicUsize,
    /// Sticky weight→lane assignment (keyed by [`WeightId`]): the lane
    /// whose LMM cache holds — or will hold — the weight's tiles.
    affinity: Mutex<HashMap<u64, usize>>,
}

impl Coordinator {
    /// Build with `lanes` IMAX lanes and a host pool.
    pub fn new(imax: ImaxConfig, lanes: usize, host_threads: usize, policy: OffloadPolicy) -> Coordinator {
        Coordinator {
            lanes: (0..lanes).map(|_| Mutex::new(LaneSim::new(imax.clone()))).collect(),
            host_threads,
            policy,
            metrics: Arc::new(CoordinatorMetrics::default()),
            next_lane: std::sync::atomic::AtomicUsize::new(0),
            affinity: Mutex::new(HashMap::new()),
        }
    }

    /// Number of lanes.
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Seed residency from a compiled [`OpPlan`]: shard the
    /// offload-eligible weights across lanes hottest-first (so each
    /// lane's cache serves a disjoint, load-balanced slice of the
    /// model), and pin each lane's share while it fits that lane's
    /// cache budget.
    pub fn apply_plan(&self, plan: &OpPlan) {
        if self.lanes.is_empty() {
            return;
        }
        let mut map = self.affinity.lock().unwrap();
        let mut remaining: Vec<usize> = self
            .lanes
            .iter()
            .map(|l| l.lock().unwrap().lmm.cache_budget())
            .collect();
        for (rank, wu) in plan.weight_uses().iter().enumerate() {
            let idx = rank % self.lanes.len();
            map.insert(wu.wid.0, idx);
            if wu.bytes <= remaining[idx] {
                remaining[idx] -= wu.bytes;
                self.lanes[idx].lock().unwrap().pin_weight(wu.wid);
            }
        }
    }

    /// Pick the lane for a job: follow the weight's affinity when it has
    /// one, assign a sticky lane on first sight, round-robin anonymous
    /// weights.
    fn pick_lane(&self, wid: Option<WeightId>) -> usize {
        let rr = || {
            self.next_lane.fetch_add(1, std::sync::atomic::Ordering::Relaxed) % self.lanes.len()
        };
        match wid {
            Some(id) => {
                let mut map = self.affinity.lock().unwrap();
                match map.entry(id.0) {
                    std::collections::hash_map::Entry::Occupied(e) => {
                        self.metrics
                            .affinity_hits
                            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        *e.get()
                    }
                    std::collections::hash_map::Entry::Vacant(v) => {
                        let idx = rr();
                        v.insert(idx);
                        idx
                    }
                }
            }
            None => rr(),
        }
    }

    /// Execute one job synchronously, routing by policy. Returns the
    /// `[n, m]` f32 output.
    pub fn execute(&self, job: &MatMulJob) -> Tensor {
        self.execute_ref(&job.w, &job.x)
    }

    /// [`Coordinator::execute`] over borrowed tensors — the seam the
    /// serving batcher uses (its weights live inside a shared
    /// [`crate::sd::pipeline::Pipeline`], not inside `Arc`ed jobs).
    pub fn execute_ref(&self, w: &Tensor, x: &Tensor) -> Tensor {
        if self.policy.offloads(w) && !self.lanes.is_empty() {
            self.execute_on_lane_ref(w, x)
        } else {
            self.metrics.record_host((w.rows * w.cols * x.rows) as u64);
            ggml::mul_mat(w, x, self.host_threads)
        }
    }

    /// Execute a batch of jobs, pulled by a pool of host threads
    /// (round-robining lane jobs over lanes). Results in submission
    /// order. Each job is submitted individually — see
    /// [`Coordinator::execute_coalesced`] for the merged-submission
    /// variant.
    pub fn execute_batch(&self, jobs: &[MatMulJob]) -> Vec<Tensor> {
        let slots: Vec<Mutex<Option<Tensor>>> =
            (0..jobs.len()).map(|_| Mutex::new(None)).collect();
        let next = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..self.host_threads.max(1) {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= jobs.len() {
                        break;
                    }
                    let r = self.execute(&jobs[i]);
                    *slots[i].lock().unwrap() = Some(r);
                });
            }
        });
        slots
            .into_iter()
            .map(|s| s.into_inner().unwrap().expect("all jobs completed"))
            .collect()
    }

    /// Execute a batch with shape-keyed coalescing: lane-eligible jobs
    /// sharing the *same weight tensor* (same `Arc`) are merged into one
    /// submission whose activation rows are the concatenation of the
    /// members' rows, and merged groups are ordered by kernel kind to
    /// avoid CONF switches. Outputs are returned per job, in submission
    /// order, **bit-identical** to executing each job alone (each output
    /// row is an independent vec-dot of the same operands).
    pub fn execute_coalesced(&self, jobs: &[MatMulJob]) -> Vec<Tensor> {
        let mut out: Vec<Option<Tensor>> = (0..jobs.len()).map(|_| None).collect();
        // Group lane jobs by weight identity; host jobs run individually.
        let mut host_jobs: Vec<usize> = Vec::new();
        let mut groups: Vec<Vec<usize>> = Vec::new();
        let mut by_weight: HashMap<usize, usize> = HashMap::new();
        for (i, job) in jobs.iter().enumerate() {
            if self.policy.offloads(&job.w) && !self.lanes.is_empty() {
                let key = Arc::as_ptr(&job.w) as usize;
                match by_weight.entry(key) {
                    std::collections::hash_map::Entry::Occupied(e) => groups[*e.get()].push(i),
                    std::collections::hash_map::Entry::Vacant(v) => {
                        v.insert(groups.len());
                        groups.push(vec![i]);
                    }
                }
            } else {
                host_jobs.push(i);
            }
        }
        // Order merged groups by shape key: same-kernel (and, within a
        // kernel, same-geometry) groups submit back-to-back, so a lane
        // re-hit by consecutive submissions avoids CONF reconfiguration.
        groups.sort_by_key(|members| {
            let key = jobs[members[0]].shape_key();
            (key.dtype.name(), key.m, key.k)
        });

        for members in &groups {
            let w = &jobs[members[0]].w;
            if members.len() == 1 {
                let i = members[0];
                out[i] = Some(self.execute_on_lane_ref(w, &jobs[i].x));
                continue;
            }
            // Concatenate activation rows across the member jobs.
            let k = w.cols;
            let total_rows: usize = members.iter().map(|&i| jobs[i].x.rows).sum();
            let mut data = Vec::with_capacity(total_rows * k);
            for &i in members {
                assert_eq!(jobs[i].x.cols, k, "coalesced jobs must share K");
                data.extend_from_slice(jobs[i].x.as_f32());
            }
            let x_cat = Tensor::f32(total_rows, k, data);
            let y = self.execute_on_lane_ref(w, &x_cat); // [total_rows, m]
            self.metrics.record_batch(members.len() as u64);
            // Split the stacked output rows back per job.
            let m = w.rows;
            let mut row = 0;
            for &i in members {
                let n_i = jobs[i].x.rows;
                let slice = &y.as_f32()[row * m..(row + n_i) * m];
                out[i] = Some(Tensor::f32(n_i, m, slice.to_vec()));
                row += n_i;
            }
        }
        for &i in &host_jobs {
            self.metrics.record_host(jobs[i].macs());
            out[i] = Some(ggml::mul_mat(&jobs[i].w, &jobs[i].x, self.host_threads));
        }
        out.into_iter().map(|t| t.expect("all jobs executed")).collect()
    }

    fn execute_on_lane_ref(&self, w: &Tensor, x: &Tensor) -> Tensor {
        let idx = self.pick_lane(w.wid);
        let (m, n, k) = (w.rows, x.rows, w.cols);
        let macs = (m * k * n) as u64;
        // Host-side marshalling happens on the calling (host) thread.
        match &w.data {
            crate::ggml::tensor::Storage::Q8_0(blocks) => {
                let acts: Vec<_> = (0..n)
                    .flat_map(|r| q8_0::quantize_row(x.row_f32(r)))
                    .collect();
                let mut lane = self.lanes[idx].lock().unwrap();
                let before = lane.cache_stats();
                let (data, bd) = lane
                    .mul_mat_q8_0_cached(w.wid, blocks, m, &acts, n, k)
                    .expect("job shapes fit LMM");
                self.metrics.record_cache(lane.cache_stats() - before);
                self.metrics.record_offload(macs, bd.total());
                Tensor::f32(n, m, data)
            }
            crate::ggml::tensor::Storage::Q3K(blocks) => {
                let acts: Vec<_> = (0..n)
                    .flat_map(|r| q8_k::quantize_row(x.row_f32(r)))
                    .collect();
                let mut lane = self.lanes[idx].lock().unwrap();
                let before = lane.cache_stats();
                let (data, bd) = lane
                    .mul_mat_q3_k_cached(w.wid, blocks, m, &acts, n, k)
                    .expect("job shapes fit LMM");
                self.metrics.record_cache(lane.cache_stats() - before);
                self.metrics.record_offload(macs, bd.total());
                Tensor::f32(n, m, data)
            }
            _ => unreachable!("policy only offloads quantized weights"),
        }
    }
}

/// Helper: build a quantized job from f32 weights.
pub fn make_job(name: &str, w_f32: Tensor, dtype: DType, x: Tensor) -> MatMulJob {
    let w = match dtype {
        DType::F32 => w_f32,
        _ => w_f32.quantize(dtype),
    };
    MatMulJob { name: name.to_string(), w: Arc::new(w), x: Arc::new(x) }
}

// Re-exports used in tests and examples.
pub use crate::ggml::tensor::Storage;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ggml::q3_k;
    use crate::util::rng::Xoshiro256pp;

    fn rnd(rows: usize, cols: usize, seed: u64) -> Tensor {
        let mut r = Xoshiro256pp::seed_from_u64(seed);
        let mut v = vec![0.0f32; rows * cols];
        r.fill_normal(&mut v, 0.5);
        Tensor::f32(rows, cols, v)
    }

    fn coordinator(lanes: usize) -> Coordinator {
        Coordinator::new(ImaxConfig::fpga(1), lanes, 2, OffloadPolicy::QuantizedOnly)
    }

    #[test]
    fn routes_by_policy_and_counts() {
        let c = coordinator(2);
        let jq = make_job("q", rnd(4, 64, 1), DType::Q8_0, rnd(3, 64, 2));
        let jf = make_job("f", rnd(4, 64, 3), DType::F16, rnd(3, 64, 4));
        c.execute(&jq);
        c.execute(&jf);
        assert_eq!(c.metrics.offloaded_jobs.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(c.metrics.host_jobs.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert!(c.metrics.offload_ratio() > 0.0);
    }

    #[test]
    fn coordinator_matches_direct_ggml_q8_0() {
        let c = coordinator(3);
        let w = rnd(6, 128, 5);
        let x = rnd(4, 128, 6);
        let job = make_job("m", w.clone(), DType::Q8_0, x.clone());
        let got = c.execute(&job);
        let want = ggml::mul_mat(&w.quantize(DType::Q8_0), &x, 1);
        for (a, b) in got.as_f32().iter().zip(want.as_f32()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn batch_preserves_order_and_uses_all_lanes() {
        let c = coordinator(4);
        let jobs: Vec<_> = (0..12)
            .map(|i| make_job(&format!("j{i}"), rnd(2, 64, 10 + i), DType::Q8_0, rnd(2, 64, 50 + i)))
            .collect();
        let outs = c.execute_batch(&jobs);
        assert_eq!(outs.len(), 12);
        // Verify each against direct computation (order preserved).
        for (job, out) in jobs.iter().zip(&outs) {
            let want = ggml::mul_mat(&job.w, &job.x, 1);
            assert_eq!(out.as_f32(), want.as_f32());
        }
        assert_eq!(
            c.metrics.offloaded_jobs.load(std::sync::atomic::Ordering::Relaxed),
            12
        );
    }

    #[test]
    fn host_only_policy_never_offloads() {
        let c = Coordinator::new(ImaxConfig::fpga(1), 2, 2, OffloadPolicy::HostOnly);
        let job = make_job("q", rnd(2, 64, 7), DType::Q8_0, rnd(2, 64, 8));
        c.execute(&job);
        assert_eq!(c.metrics.offloaded_jobs.load(std::sync::atomic::Ordering::Relaxed), 0);
    }

    #[test]
    fn q3k_jobs_route_and_compute() {
        let c = coordinator(1);
        let w = rnd(3, 256, 9);
        let x = rnd(2, 256, 10);
        let job = make_job("q3", w.clone(), DType::Q3K, x.clone());
        let got = c.execute(&job);
        // Lane computes the imax5 (5-bit scale) variant.
        let wq = w.quantize(DType::Q3K);
        let blocks = match &wq.data {
            Storage::Q3K(b) => b.clone(),
            _ => unreachable!(),
        };
        let acts: Vec<_> = (0..2).flat_map(|r| q8_k::quantize_row(x.row_f32(r))).collect();
        for a_row in 0..2 {
            for w_row in 0..3 {
                let want = q3_k::vec_dot_imax5(&blocks[w_row..w_row + 1], &acts[a_row..a_row + 1]);
                assert_eq!(got.as_f32()[a_row * 3 + w_row].to_bits(), want.to_bits());
            }
        }
    }

    #[test]
    fn shape_key_groups_same_geometry() {
        let a = make_job("a", rnd(4, 64, 1), DType::Q8_0, rnd(3, 64, 2));
        let b = make_job("b", rnd(4, 64, 3), DType::Q8_0, rnd(7, 64, 4));
        let c = make_job("c", rnd(8, 64, 5), DType::Q8_0, rnd(3, 64, 6));
        assert_eq!(a.shape_key(), b.shape_key(), "N does not enter the key");
        assert_ne!(a.shape_key(), c.shape_key(), "M does");
        assert_eq!(a.shape_key(), ShapeKey { dtype: DType::Q8_0, m: 4, k: 64 });
    }

    #[test]
    fn coalesced_bit_identical_to_serial() {
        // Three requests hitting the same two weight tensors, plus one
        // host (F16) job: coalesced outputs must match per-job execution
        // bit-for-bit, in submission order.
        let w1 = Arc::new(rnd(6, 128, 1).quantize(DType::Q8_0));
        let w2 = Arc::new(rnd(4, 256, 2).quantize(DType::Q3K));
        let wf = Arc::new(rnd(5, 64, 3).quantize(DType::F16));
        let mut jobs = Vec::new();
        for r in 0..3u64 {
            jobs.push(MatMulJob {
                name: format!("r{r}.l1"),
                w: Arc::clone(&w1),
                x: Arc::new(rnd(2 + r as usize, 128, 10 + r)),
            });
            jobs.push(MatMulJob {
                name: format!("r{r}.l2"),
                w: Arc::clone(&w2),
                x: Arc::new(rnd(3, 256, 20 + r)),
            });
        }
        jobs.push(MatMulJob { name: "host".into(), w: wf, x: Arc::new(rnd(2, 64, 30)) });

        let serial = coordinator(2);
        let want: Vec<Tensor> = jobs.iter().map(|j| serial.execute(j)).collect();
        let batched = coordinator(2);
        let got = batched.execute_coalesced(&jobs);
        assert_eq!(got.len(), want.len());
        for (g, w_) in got.iter().zip(&want) {
            assert_eq!((g.rows, g.cols), (w_.rows, w_.cols));
            for (a, b) in g.as_f32().iter().zip(w_.as_f32()) {
                assert_eq!(a.to_bits(), b.to_bits(), "batched == serial bit-exact");
            }
        }
    }

    #[test]
    fn coalescing_merges_submissions_and_saves_cycles() {
        let w = Arc::new(rnd(8, 128, 1).quantize(DType::Q8_0));
        let jobs: Vec<MatMulJob> = (0..6u64)
            .map(|r| MatMulJob {
                name: format!("r{r}"),
                w: Arc::clone(&w),
                x: Arc::new(rnd(4, 128, 40 + r)),
            })
            .collect();

        let serial = coordinator(1);
        for j in &jobs {
            serial.execute(j);
        }
        let batched = coordinator(1);
        batched.execute_coalesced(&jobs);

        let ord = std::sync::atomic::Ordering::Relaxed;
        assert_eq!(serial.metrics.offloaded_jobs.load(ord), 6);
        assert_eq!(batched.metrics.offloaded_jobs.load(ord), 1, "one merged submission");
        assert_eq!(batched.metrics.batched_submissions.load(ord), 1);
        assert_eq!(batched.metrics.coalesced_jobs.load(ord), 6);
        assert_eq!(
            serial.metrics.offloaded_macs.load(ord),
            batched.metrics.offloaded_macs.load(ord),
            "same work either way"
        );
        assert!(
            batched.metrics.imax_cycles.load(ord) < serial.metrics.imax_cycles.load(ord),
            "batched submission amortizes DMA setup + weight streaming: {} vs {}",
            batched.metrics.imax_cycles.load(ord),
            serial.metrics.imax_cycles.load(ord)
        );
    }

    #[test]
    fn residency_affinity_routes_weight_to_one_lane_and_reuses_cache() {
        let c = coordinator(3);
        let w = Arc::new(
            rnd(6, 128, 40).quantize(DType::Q8_0).with_wid(crate::ggml::WeightId(77)),
        );
        let ord = std::sync::atomic::Ordering::Relaxed;
        for i in 0..4u64 {
            let job = MatMulJob {
                name: format!("j{i}"),
                w: Arc::clone(&w),
                x: Arc::new(rnd(2, 128, 60 + i)),
            };
            let got = c.execute(&job);
            let want = ggml::mul_mat(&w, &job.x, 1);
            for (a, b) in got.as_f32().iter().zip(want.as_f32()) {
                assert_eq!(a.to_bits(), b.to_bits(), "cached execution stays bit-exact");
            }
        }
        assert_eq!(c.metrics.affinity_hits.load(ord), 3, "first call assigns, rest follow");
        assert_eq!(c.metrics.cache_misses.load(ord), 1, "one cold fill");
        assert_eq!(c.metrics.cache_hits.load(ord), 3, "later jobs find the weight resident");
        assert!(c.metrics.cache_hit_bytes.load(ord) > 0);
        assert!((c.metrics.cache_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn apply_plan_preassigns_affinity_before_first_execution() {
        use crate::sd::plan::{OpPlan, OpSite};
        let c = coordinator(2);
        let site = |seq: usize, wid: u64, bytes: usize| OpSite {
            seq,
            wid: Some(crate::ggml::WeightId(wid)),
            dtype: DType::Q8_0,
            m: 4,
            k: 128,
            n: 2,
            weight_bytes: bytes,
        };
        let plan = OpPlan { sites: vec![site(0, 1, 4 * 136), site(1, 2, 4 * 136)] };
        c.apply_plan(&plan);
        let ord = std::sync::atomic::Ordering::Relaxed;
        let w = Arc::new(
            rnd(4, 128, 50).quantize(DType::Q8_0).with_wid(crate::ggml::WeightId(1)),
        );
        let job = MatMulJob { name: "a".into(), w, x: Arc::new(rnd(2, 128, 51)) };
        c.execute(&job);
        assert_eq!(
            c.metrics.affinity_hits.load(ord),
            1,
            "the plan pre-assigned this weight's lane"
        );
        c.execute(&job);
        assert_eq!(c.metrics.cache_hits.load(ord), 1, "second call hits the pinned resident");
    }

    #[test]
    fn coalesced_handles_empty_and_singleton() {
        let c = coordinator(2);
        assert!(c.execute_coalesced(&[]).is_empty());
        let job = make_job("solo", rnd(4, 64, 1), DType::Q8_0, rnd(2, 64, 2));
        let got = c.execute_coalesced(std::slice::from_ref(&job));
        let want = c.execute(&job);
        assert_eq!(got[0].as_f32(), want.as_f32());
    }
}
