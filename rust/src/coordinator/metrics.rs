//! Coordinator counters (thread-safe).

use crate::imax::lmm::CacheStats;
use std::sync::atomic::{AtomicU64, Ordering};

/// Shared metrics for a coordinator instance.
#[derive(Debug, Default)]
pub struct CoordinatorMetrics {
    /// Jobs executed on the host pool.
    pub host_jobs: AtomicU64,
    /// Jobs executed on IMAX lanes.
    pub offloaded_jobs: AtomicU64,
    /// Total MACs routed to IMAX.
    pub offloaded_macs: AtomicU64,
    /// Total MACs kept on host.
    pub host_macs: AtomicU64,
    /// Cumulative simulated IMAX cycles across lanes.
    pub imax_cycles: AtomicU64,
    /// Merged lane submissions covering more than one job.
    pub batched_submissions: AtomicU64,
    /// Jobs folded into merged submissions.
    pub coalesced_jobs: AtomicU64,
    /// Ops executed through the sharded submission path
    /// ([`crate::coordinator::Coordinator::submit_sharded`]).
    pub sharded_ops: AtomicU64,
    /// Per-lane shard submissions those ops decomposed into (an op
    /// splits into `min(m, max(lanes, ceil(m/cap)))` shards, so this
    /// equals `sharded_ops` only on single-lane/single-row runs).
    pub shard_submissions: AtomicU64,
    /// Lane selections that followed an existing weight→lane affinity
    /// (the weight's cached tiles were on the chosen lane).
    pub affinity_hits: AtomicU64,
    /// Weight-cache lookups that hit, summed over lanes.
    pub cache_hits: AtomicU64,
    /// Weight-cache lookups that missed, summed over lanes.
    pub cache_misses: AtomicU64,
    /// Weight LOAD bytes skipped thanks to residency.
    pub cache_hit_bytes: AtomicU64,
    /// Weight bytes DMA'd on cache misses.
    pub cache_miss_bytes: AtomicU64,
    /// Bytes freed by LRU eviction across lanes.
    pub cache_evicted_bytes: AtomicU64,
    /// Cache inserts the lanes rejected (weight larger than the
    /// unpinned budget) — the canary for a mis-sized pin/prefetch pass:
    /// a healthy plan keeps this at 0 for every pinned weight.
    pub cache_insert_failures: AtomicU64,
}

/// Point-in-time copy of every [`CoordinatorMetrics`] counter.
///
/// Unlike the live struct (whose fields are atomics and therefore not
/// comparable), a snapshot derives `PartialEq`/`Eq`, so determinism
/// tests can assert that a parallel run produced *exactly* the same
/// counters as a sequential one:
///
/// ```rust
/// use imax_sd::coordinator::metrics::CoordinatorMetrics;
///
/// let a = CoordinatorMetrics::default();
/// let b = CoordinatorMetrics::default();
/// a.record_offload(100, 42);
/// b.record_offload(100, 42);
/// assert_eq!(a.snapshot(), b.snapshot());
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub host_jobs: u64,
    pub offloaded_jobs: u64,
    pub offloaded_macs: u64,
    pub host_macs: u64,
    pub imax_cycles: u64,
    pub batched_submissions: u64,
    pub coalesced_jobs: u64,
    pub sharded_ops: u64,
    pub shard_submissions: u64,
    pub affinity_hits: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_hit_bytes: u64,
    pub cache_miss_bytes: u64,
    pub cache_evicted_bytes: u64,
    pub cache_insert_failures: u64,
}

impl CoordinatorMetrics {
    /// Capture every counter into a comparable [`MetricsSnapshot`].
    ///
    /// Loads are relaxed and non-atomic as a set: call this only when no
    /// submissions are in flight (e.g. after `sync`ing every handle).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let ld = |a: &AtomicU64| a.load(Ordering::Relaxed);
        MetricsSnapshot {
            host_jobs: ld(&self.host_jobs),
            offloaded_jobs: ld(&self.offloaded_jobs),
            offloaded_macs: ld(&self.offloaded_macs),
            host_macs: ld(&self.host_macs),
            imax_cycles: ld(&self.imax_cycles),
            batched_submissions: ld(&self.batched_submissions),
            coalesced_jobs: ld(&self.coalesced_jobs),
            sharded_ops: ld(&self.sharded_ops),
            shard_submissions: ld(&self.shard_submissions),
            affinity_hits: ld(&self.affinity_hits),
            cache_hits: ld(&self.cache_hits),
            cache_misses: ld(&self.cache_misses),
            cache_hit_bytes: ld(&self.cache_hit_bytes),
            cache_miss_bytes: ld(&self.cache_miss_bytes),
            cache_evicted_bytes: ld(&self.cache_evicted_bytes),
            cache_insert_failures: ld(&self.cache_insert_failures),
        }
    }

    /// Offload ratio by MACs in `[0, 1]`.
    pub fn offload_ratio(&self) -> f64 {
        let off = self.offloaded_macs.load(Ordering::Relaxed) as f64;
        let host = self.host_macs.load(Ordering::Relaxed) as f64;
        if off + host == 0.0 {
            0.0
        } else {
            off / (off + host)
        }
    }

    /// Record a host job.
    pub fn record_host(&self, macs: u64) {
        self.host_jobs.fetch_add(1, Ordering::Relaxed);
        self.host_macs.fetch_add(macs, Ordering::Relaxed);
    }

    /// Record an offloaded job.
    pub fn record_offload(&self, macs: u64, cycles: u64) {
        self.offloaded_jobs.fetch_add(1, Ordering::Relaxed);
        self.offloaded_macs.fetch_add(macs, Ordering::Relaxed);
        self.imax_cycles.fetch_add(cycles, Ordering::Relaxed);
    }

    /// Record a merged lane submission covering `jobs` coalesced jobs.
    pub fn record_batch(&self, jobs: u64) {
        self.batched_submissions.fetch_add(1, Ordering::Relaxed);
        self.coalesced_jobs.fetch_add(jobs, Ordering::Relaxed);
    }

    /// Record one sharded op that split into `shards` lane submissions.
    pub fn record_sharded(&self, shards: u64) {
        self.sharded_ops.fetch_add(1, Ordering::Relaxed);
        self.shard_submissions.fetch_add(shards, Ordering::Relaxed);
    }

    /// Fold one lane call's residency-cache delta into the shared totals.
    pub fn record_cache(&self, delta: CacheStats) {
        self.cache_hits.fetch_add(delta.hits, Ordering::Relaxed);
        self.cache_misses.fetch_add(delta.misses, Ordering::Relaxed);
        self.cache_hit_bytes.fetch_add(delta.hit_bytes, Ordering::Relaxed);
        self.cache_miss_bytes.fetch_add(delta.miss_bytes, Ordering::Relaxed);
        self.cache_evicted_bytes.fetch_add(delta.evicted_bytes, Ordering::Relaxed);
        self.cache_insert_failures.fetch_add(delta.insert_failures, Ordering::Relaxed);
    }

    /// Weight-cache hit rate over lookups in `[0, 1]` (delegates to
    /// [`CacheStats::hit_rate`] so the definition lives in one place).
    pub fn cache_hit_rate(&self) -> f64 {
        CacheStats {
            hits: self.cache_hits.load(Ordering::Relaxed),
            misses: self.cache_misses.load(Ordering::Relaxed),
            ..Default::default()
        }
        .hit_rate()
    }

    /// Simulated IMAX cycles per offloaded MAC (0 when nothing offloaded)
    /// — the lane-utilization figure the serving bench compares across
    /// serial and batched submission.
    pub fn cycles_per_offloaded_mac(&self) -> f64 {
        let macs = self.offloaded_macs.load(Ordering::Relaxed);
        if macs == 0 {
            0.0
        } else {
            self.imax_cycles.load(Ordering::Relaxed) as f64 / macs as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_computation() {
        let m = CoordinatorMetrics::default();
        assert_eq!(m.offload_ratio(), 0.0);
        m.record_host(300);
        m.record_offload(100, 42);
        assert!((m.offload_ratio() - 0.25).abs() < 1e-12);
        assert_eq!(m.host_jobs.load(Ordering::Relaxed), 1);
        assert_eq!(m.imax_cycles.load(Ordering::Relaxed), 42);
    }

    #[test]
    fn cache_counters_fold_deltas() {
        let m = CoordinatorMetrics::default();
        assert_eq!(m.cache_hit_rate(), 0.0);
        m.record_cache(CacheStats {
            hits: 3,
            misses: 1,
            hit_bytes: 300,
            miss_bytes: 100,
            evicted_bytes: 50,
            insert_failures: 2,
        });
        m.record_cache(CacheStats { hits: 1, ..Default::default() });
        assert_eq!(m.cache_hits.load(Ordering::Relaxed), 4);
        assert_eq!(m.cache_misses.load(Ordering::Relaxed), 1);
        assert_eq!(m.cache_hit_bytes.load(Ordering::Relaxed), 300);
        assert_eq!(m.cache_evicted_bytes.load(Ordering::Relaxed), 50);
        assert_eq!(m.cache_insert_failures.load(Ordering::Relaxed), 2);
        assert!((m.cache_hit_rate() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn snapshot_compares_equal_iff_counters_match() {
        let a = CoordinatorMetrics::default();
        let b = CoordinatorMetrics::default();
        a.record_offload(100, 42);
        a.record_sharded(4);
        b.record_offload(100, 42);
        b.record_sharded(4);
        assert_eq!(a.snapshot(), b.snapshot());
        b.record_host(1);
        assert_ne!(a.snapshot(), b.snapshot());
    }

    #[test]
    fn batch_counters_and_cycle_efficiency() {
        let m = CoordinatorMetrics::default();
        assert_eq!(m.cycles_per_offloaded_mac(), 0.0);
        m.record_offload(1000, 500);
        assert!((m.cycles_per_offloaded_mac() - 0.5).abs() < 1e-12);
        m.record_batch(4);
        m.record_batch(2);
        assert_eq!(m.batched_submissions.load(Ordering::Relaxed), 2);
        assert_eq!(m.coalesced_jobs.load(Ordering::Relaxed), 6);
    }
}
