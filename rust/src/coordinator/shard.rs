//! Row-tile sharding of one mat-mul across lanes.
//!
//! A GGML-style `mul_mat` output is `[n, m]` where every column `j` is
//! produced by an independent vec-dot of weight row `j` against each
//! activation row — so the *weight rows* are the natural shard axis: a
//! [`ShardPlan`] splits the `m` rows into contiguous ranges, assigns each
//! range to a lane, and the stitched output is **bit-identical** to the
//! unsharded op (no partial sums ever cross a shard boundary).
//!
//! Invariants (property-tested in `tests/shard_props.rs`):
//!
//! * **disjoint + covering** — the shard ranges partition `0..m` exactly,
//!   in ascending order;
//! * **balanced** — shard sizes differ by at most one row;
//! * **budget-capped** — when a per-lane cache budget is given, no shard
//!   exceeds it (`rows × row_bytes ≤ budget`) as long as a single row
//!   fits the budget at all, so every shard is *cacheable* in its lane's
//!   LMM partition; over-budget weights fall back to more, smaller
//!   shards dealt round-robin over the lanes.
//!
//! Each shard carries its own derived [`WeightId`] ([`shard_wid`]) so a
//! lane caches **only its resident shard** of the parent weight — this is
//! what turns the weight cache from a latency lever into a
//! bandwidth-scaling lever: `L` lanes hold `L×` the aggregate resident
//! bytes, and a warm step streams only the shards that did not fit.

use crate::ggml::WeightId;
use std::ops::Range;

/// One shard of a row-partitioned weight: `rows` of the parent matrix,
/// executed on `lane`, cached under `wid`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RowShard {
    /// Lane index the shard executes (and caches) on.
    pub lane: usize,
    /// Weight-row range of the parent matrix (`[start, end)`).
    pub rows: Range<usize>,
    /// Cache identity of this shard (`None` for anonymous weights, which
    /// stream transiently on every call).
    pub wid: Option<WeightId>,
}

impl RowShard {
    /// Rows in the shard.
    pub fn len(&self) -> usize {
        self.rows.end - self.rows.start
    }

    /// Whether the shard is empty (never produced by [`ShardPlan::new`]).
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

/// The row partition of one weight across the lanes.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// Total weight rows partitioned.
    pub m: usize,
    /// Shards in ascending row order (lane = (base + index) % lanes, with
    /// the base rotated by the parent weight id).
    pub shards: Vec<RowShard>,
}

impl ShardPlan {
    /// Rows of one shard that fit a lane's cache budget: `m` (no cap)
    /// when caching is disabled **or** when a single row already exceeds
    /// the budget — such a weight cannot be cached at any shard size, so
    /// it takes the plain lanes-way split and streams, rather than
    /// fragmenting into per-row submissions that would each re-load the
    /// activation rows.
    pub fn cap_rows(row_bytes: usize, cache_budget: usize, m: usize) -> usize {
        if cache_budget == 0 || row_bytes == 0 || row_bytes > cache_budget {
            m.max(1)
        } else {
            cache_budget / row_bytes
        }
    }

    /// Partition `m` rows over `lanes` lanes with at most `cap_rows` rows
    /// per shard and at least `min_rows` rows per shard where the
    /// lane-count split would go finer than that.
    ///
    /// The shard count is `min(lanes, max(1, m / min_rows))` widened to
    /// `ceil(m / cap_rows)` under cache-budget pressure and clamped to
    /// `m`. `min_rows` is the cycle-model amortization threshold (see
    /// [`crate::coordinator::Coordinator::min_shard_rows`]): a shard that
    /// would carry fewer rows than the per-shard fixed cost (DMA setup +
    /// REGV/RANGE/CONF) can pay for is not worth a lane, so tiny ops —
    /// the `TimeEmbed` GEMVs — stay on a single lane instead of
    /// splitting lanes-wide for negligible LOAD savings. `min_rows == 1`
    /// disables the threshold and reproduces the plain lanes-way split.
    /// The cache cap deliberately wins over the threshold: a weight that
    /// must fragment to stay cacheable still fragments.
    ///
    /// Sizes are balanced to within one row and shard `i` runs on lane
    /// `(base + i) % lanes`, where `base` is derived from `parent`
    /// (anonymous weights use base 0) — so single-shard ops of different
    /// weights land on *different* lanes instead of all piling onto lane
    /// 0. Shard ids derive from `parent` via [`shard_wid`]; with one
    /// shard the parent id is used unchanged, so single-lane sharded
    /// execution is cache-compatible with unsharded execution.
    pub fn new(
        m: usize,
        lanes: usize,
        cap_rows: usize,
        min_rows: usize,
        parent: Option<WeightId>,
    ) -> ShardPlan {
        assert!(m > 0, "cannot shard an empty weight");
        assert!(lanes > 0, "cannot shard over zero lanes");
        let cap = cap_rows.max(1);
        let by_min = (m / min_rows.max(1)).max(1);
        let count = lanes.min(by_min).max(m.div_ceil(cap)).min(m);
        let lane_base = parent.map(|p| (p.0 % lanes as u64) as usize).unwrap_or(0);
        let (base, rem) = (m / count, m % count);
        let mut shards = Vec::with_capacity(count);
        let mut start = 0;
        for i in 0..count {
            let len = base + usize::from(i < rem);
            let rows = start..start + len;
            start += len;
            shards.push(RowShard {
                lane: (lane_base + i) % lanes,
                rows,
                wid: parent.map(|p| shard_wid(p, i, count)),
            });
        }
        debug_assert_eq!(start, m, "shards must cover all rows");
        ShardPlan { m, shards }
    }

    /// Number of shards.
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    /// Whether the plan is trivial (no split happened).
    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// Largest shard size in rows.
    pub fn max_rows(&self) -> usize {
        self.shards.iter().map(RowShard::len).max().unwrap_or(0)
    }
}

/// Stable identity of shard `index` of `count` of a parent weight.
///
/// A pure function of `(parent, index, count)`, so the pin pass
/// ([`crate::coordinator::Coordinator::apply_plan_sharded`]) and the
/// execution path ([`crate::coordinator::Coordinator::submit_sharded`])
/// independently derive the **same** id — warm calls hit the shards the
/// plan pinned. `count == 1` returns the parent id unchanged.
pub fn shard_wid(parent: WeightId, index: usize, count: usize) -> WeightId {
    if count == 1 {
        return parent;
    }
    let mut h = parent.0 ^ 0xA076_1D64_78BD_642F;
    h = h.wrapping_mul(0x0000_0100_0000_01B3);
    h ^= ((index as u64) << 32) | count as u64;
    h = h.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    WeightId(h)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_partition(plan: &ShardPlan) {
        let mut next = 0;
        for s in &plan.shards {
            assert_eq!(s.rows.start, next, "shards must be contiguous: {plan:?}");
            assert!(!s.is_empty(), "empty shard: {plan:?}");
            next = s.rows.end;
        }
        assert_eq!(next, plan.m, "shards must cover all rows: {plan:?}");
    }

    #[test]
    fn balanced_split_over_lanes() {
        let p = ShardPlan::new(10, 4, usize::MAX, 1, None);
        assert_partition(&p);
        assert_eq!(p.len(), 4);
        let sizes: Vec<_> = p.shards.iter().map(RowShard::len).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
        assert_eq!(
            p.shards.iter().map(|s| s.lane).collect::<Vec<_>>(),
            vec![0, 1, 2, 3]
        );
    }

    #[test]
    fn fewer_rows_than_lanes_caps_shard_count() {
        let p = ShardPlan::new(3, 8, usize::MAX, 1, None);
        assert_partition(&p);
        assert_eq!(p.len(), 3, "no empty shards");
    }

    #[test]
    fn cache_cap_splits_finer_and_respects_budget() {
        // 100 rows of 10 B over 2 lanes with a 200 B budget: cap is 20
        // rows, so 5 shards of ≤ 20 rows dealt round-robin starting from
        // the parent-rotated base lane (7 % 2 = 1).
        let cap = ShardPlan::cap_rows(10, 200, 100);
        assert_eq!(cap, 20);
        let p = ShardPlan::new(100, 2, cap, 1, Some(WeightId(7)));
        assert_partition(&p);
        assert_eq!(p.len(), 5);
        assert!(p.max_rows() <= cap);
        assert_eq!(
            p.shards.iter().map(|s| s.lane).collect::<Vec<_>>(),
            vec![1, 0, 1, 0, 1]
        );
    }

    #[test]
    fn min_rows_threshold_keeps_tiny_ops_on_one_lane() {
        // A 256-row GEMV whose cycle-model threshold says shards below
        // 140 rows cannot amortize their fixed cost: one shard, not 8.
        let p = ShardPlan::new(256, 8, usize::MAX, 140, Some(WeightId(3)));
        assert_partition(&p);
        assert_eq!(p.len(), 1, "tiny GEMV stays single-lane");
        assert_eq!(p.shards[0].wid, Some(WeightId(3)), "single shard keeps the parent id");
        // Headroom for exactly three threshold-sized shards: split three ways.
        let p = ShardPlan::new(256, 8, usize::MAX, 80, None);
        assert_partition(&p);
        assert_eq!(p.len(), 3);
        // min_rows == 1 reproduces the plain lanes-way split.
        let p = ShardPlan::new(256, 8, usize::MAX, 1, None);
        assert_eq!(p.len(), 8);
    }

    #[test]
    fn cache_cap_wins_over_min_rows_threshold() {
        // The budget forces ≤ 16-row shards even though the threshold
        // alone would keep the op whole: cacheability beats amortization.
        let p = ShardPlan::new(64, 2, 16, 999, Some(WeightId(1)));
        assert_partition(&p);
        assert_eq!(p.len(), 4);
        assert!(p.max_rows() <= 16);
    }

    #[test]
    fn base_lane_rotates_with_parent_id() {
        // Single-shard ops of different weights spread over the lanes
        // instead of all landing on lane 0.
        for lanes in [2usize, 4, 8] {
            for wid in 0..32u64 {
                let p = ShardPlan::new(16, lanes, usize::MAX, 999, Some(WeightId(wid)));
                assert_eq!(p.len(), 1);
                assert_eq!(p.shards[0].lane, (wid % lanes as u64) as usize);
            }
        }
        // Anonymous weights keep base 0.
        let p = ShardPlan::new(16, 4, usize::MAX, 999, None);
        assert_eq!(p.shards[0].lane, 0);
    }

    #[test]
    fn cap_rows_disabled_cache_means_no_cap() {
        assert_eq!(ShardPlan::cap_rows(10, 0, 64), 64);
        // A row bigger than the budget is uncacheable at any shard size:
        // no cap either (plain lanes-way split, shards stream).
        assert_eq!(ShardPlan::cap_rows(500, 200, 64), 64);
    }

    #[test]
    fn shard_wids_are_stable_distinct_and_identity_for_single() {
        let parent = WeightId(0xBEEF);
        assert_eq!(shard_wid(parent, 0, 1), parent, "unsharded keeps the parent id");
        let a = shard_wid(parent, 0, 4);
        let b = shard_wid(parent, 1, 4);
        assert_ne!(a, b, "index enters the id");
        assert_ne!(a, shard_wid(parent, 0, 2), "count enters the id");
        assert_ne!(a.0, parent.0, "shard ids do not collide with the parent");
        assert_eq!(a, shard_wid(parent, 0, 4), "pure function of the inputs");
        assert_ne!(a, shard_wid(WeightId(0xF00D), 0, 4), "parent enters the id");
    }

    #[test]
    fn plan_ids_match_independent_derivation() {
        let parent = WeightId(42);
        let p = ShardPlan::new(64, 4, 16, 1, Some(parent));
        for (i, s) in p.shards.iter().enumerate() {
            assert_eq!(s.wid, Some(shard_wid(parent, i, p.len())));
        }
        let anon = ShardPlan::new(64, 4, 16, 1, None);
        assert!(anon.shards.iter().all(|s| s.wid.is_none()));
    }
}
