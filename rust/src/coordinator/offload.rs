//! Offload policy: which mat-muls go to IMAX.

use crate::ggml::{DType, Tensor};

/// Routing policy for mat-mul jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OffloadPolicy {
    /// The paper's policy (§III-B): only the model's quantized kernels
    /// (Q8_0 / Q3_K weights) are offloaded; F16/F32 stay on the host.
    QuantizedOnly,
    /// Everything on the host (the "standalone ARM" baseline).
    HostOnly,
}

impl OffloadPolicy {
    /// Decide for a weight tensor.
    pub fn offloads(self, w: &Tensor) -> bool {
        match self {
            OffloadPolicy::HostOnly => false,
            OffloadPolicy::QuantizedOnly => {
                matches!(w.dtype(), DType::Q8_0 | DType::Q3K)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantized_only_routes_by_dtype() {
        let f = Tensor::f32(2, 64, vec![0.1; 128]);
        let q = f.quantize(DType::Q8_0);
        let h = f.quantize(DType::F16);
        let p = OffloadPolicy::QuantizedOnly;
        assert!(p.offloads(&q));
        assert!(!p.offloads(&h));
        assert!(!p.offloads(&f));
        assert!(!OffloadPolicy::HostOnly.offloads(&q));
    }
}
