//! Offload policy: which mat-muls go to IMAX.

use crate::ggml::{DType, Tensor};
use crate::sd::backend::OpKind;

/// Routing policy for mat-mul jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OffloadPolicy {
    /// The paper's policy (§III-B): only the model's quantized kernels
    /// (Q8_0 / Q3_K weights) are offloaded; F16/F32 stay on the host.
    QuantizedOnly,
    /// [`OffloadPolicy::QuantizedOnly`] plus the §VI extension: F16
    /// `ConvIm2col` GEMMs (the pipeline's dominant MAC population,
    /// Table I) run on the lane via the OP_SML16 kernel. F16 *linear*
    /// fallback weights and all F32 ops still stay on the host — the
    /// policy is kind-aware, not a blanket dtype rule.
    QuantizedAndConv,
    /// Everything on the host (the "standalone ARM" baseline).
    HostOnly,
}

impl OffloadPolicy {
    /// Decide for a weight tensor alone (dtype-only view; used where the
    /// op kind is unknown). F16 never offloads on this view — conv
    /// routing needs the kind and goes through
    /// [`OffloadPolicy::offloads_op`].
    pub fn offloads(self, w: &Tensor) -> bool {
        match self {
            OffloadPolicy::HostOnly => false,
            OffloadPolicy::QuantizedOnly | OffloadPolicy::QuantizedAndConv => {
                matches!(w.dtype(), DType::Q8_0 | DType::Q3K)
            }
        }
    }

    /// Decide for a weight tensor under a specific op kind — the full
    /// routing rule every submission path consults. Quantized weights
    /// offload regardless of kind; F16 offloads only for `ConvIm2col`
    /// and only under [`OffloadPolicy::QuantizedAndConv`].
    pub fn offloads_op(self, w: &Tensor, kind: OpKind) -> bool {
        self.offloads(w)
            || (self == OffloadPolicy::QuantizedAndConv
                && w.dtype() == DType::F16
                && matches!(kind, OpKind::ConvIm2col { .. }))
    }

    /// Decide for a plan-aggregated weight that is already known to be
    /// lane-eligible by kind (see
    /// [`crate::sd::plan::OpSite::offload_eligible`] — the only F16
    /// entries a plan aggregates are conv sites). The prefetch/pin
    /// passes use this so a quantized-only run never wastes cache budget
    /// pinning conv weights it will execute on the host.
    pub fn offloads_use(self, dtype: DType) -> bool {
        match self {
            OffloadPolicy::HostOnly => false,
            OffloadPolicy::QuantizedOnly => matches!(dtype, DType::Q8_0 | DType::Q3K),
            OffloadPolicy::QuantizedAndConv => {
                matches!(dtype, DType::Q8_0 | DType::Q3K | DType::F16)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantized_only_routes_by_dtype() {
        let f = Tensor::f32(2, 64, vec![0.1; 128]);
        let q = f.quantize(DType::Q8_0);
        let h = f.quantize(DType::F16);
        let p = OffloadPolicy::QuantizedOnly;
        assert!(p.offloads(&q));
        assert!(!p.offloads(&h));
        assert!(!p.offloads(&f));
        assert!(!OffloadPolicy::HostOnly.offloads(&q));
    }

    #[test]
    fn conv_policy_is_kind_aware() {
        let f = Tensor::f32(2, 18, vec![0.1; 36]);
        let q = Tensor::f32(2, 64, vec![0.1; 128]).quantize(DType::Q8_0);
        let h = f.quantize(DType::F16);
        let conv = OpKind::ConvIm2col { k: 3, stride: 1 };
        let p = OffloadPolicy::QuantizedAndConv;
        // F16 conv sites offload; F16 linears and F32 convs do not.
        assert!(p.offloads_op(&h, conv));
        assert!(!p.offloads_op(&h, OpKind::Linear));
        assert!(!p.offloads_op(&f, conv));
        // Quantized weights offload under any kind, as before.
        assert!(p.offloads_op(&q, OpKind::Linear));
        assert!(p.offloads(&q));
        // The dtype-only view still refuses F16 (no kind to judge by).
        assert!(!p.offloads(&h));
        // QuantizedOnly never offloads F16 convs (the --conv-offload=off
        // baseline), and HostOnly refuses everything.
        assert!(!OffloadPolicy::QuantizedOnly.offloads_op(&h, conv));
        assert!(!OffloadPolicy::HostOnly.offloads_op(&q, OpKind::Linear));
    }
}
