//! Regenerates **Fig. 9** (Q3_K) and **Fig. 10** (Q8_0): offloaded-kernel
//! execution time vs. thread/lane count (1–8) per device.
//!
//! Paper findings: the 145 MHz FPGA beats the ARM host at 1 lane; the
//! 840 MHz ASIC is competitive with the Xeon; the GPU stays ahead; IMAX
//! scales efficiently to 2 lanes then saturates (dual-core host supply,
//! §V-A).

use imax_sd::device::{arm_a72, gtx_1080ti, xeon_w5, Device, ImaxDevice};
use imax_sd::sd::arch::sd_turbo_512;
use imax_sd::sd::QuantModel;
use imax_sd::util::tables::Table;

fn main() {
    let trace = sd_turbo_512(1);
    for (fig, model) in [(9, QuantModel::Q3K), (10, QuantModel::Q8_0)] {
        let mut t = Table::new(
            &format!(
                "Fig. {fig}: {} kernel execution time (s) vs threads/lanes",
                model.name()
            ),
            &["Device", "1", "2", "3", "4", "6", "8"],
        );
        let devs: Vec<Box<dyn Device>> = vec![
            Box::new(arm_a72()),
            Box::new(ImaxDevice::fpga(1)),
            Box::new(ImaxDevice::asic(1)),
            Box::new(xeon_w5()),
            Box::new(gtx_1080ti()),
        ];
        for d in &devs {
            let mut row = vec![d.name()];
            for lanes in [1usize, 2, 3, 4, 6, 8] {
                row.push(format!("{:.2}", d.kernel_seconds(&trace, model, lanes)));
            }
            t.row(&row);
        }
        t.print();
        println!();
    }
    println!("shape checks: FPGA(1) < ARM(1); ASIC ~ Xeon(16t); knee at 2 lanes");
}
