//! Design-choice ablations called out in DESIGN.md:
//!
//! 1. **5-bit scale approximation** (§III-B "almost no effect"):
//!    dequantization RMSE and dot-product error, exact 6-bit vs OP_CVT53
//!    5-bit scales.
//! 2. **LMM capacity sweep**: LOAD amplification vs LMM size (the 512 KB
//!    configuration is the paper's; smaller LMMs re-stream weights more).
//! 3. **Lane-group geometry**: EXEC cycles per MAC for the two kernel
//!    mappings (46 vs 51 PEs).

use imax_sd::ggml::{q3_k, q8_k};
use imax_sd::imax::lane::{LaneSim, TilePlan};
use imax_sd::imax::{ImaxConfig, KernelConfig, KernelKind};
use imax_sd::util::rng::Xoshiro256pp;
use imax_sd::util::tables::Table;

fn random(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    let mut v = vec![0.0f32; n];
    r.fill_normal(&mut v, 0.8);
    v
}

fn main() {
    // --- Ablation 1: 5-bit scale approximation.
    let n = 256 * 64;
    let x = random(n, 1);
    let blocks = q3_k::quantize_row(&x);
    let exact = q3_k::dequantize_row(&blocks);
    let approx = q3_k::dequantize_row_imax5(&blocks);
    let den: f32 = x.iter().map(|v| v * v).sum();
    let rmse = |y: &[f32]| {
        (x.iter().zip(y).map(|(a, b)| (a - b).powi(2)).sum::<f32>() / den).sqrt()
    };
    let acts = q8_k::quantize_row(&random(n, 2));
    let d_exact = q3_k::vec_dot(&blocks, &acts);
    let d_approx = q3_k::vec_dot_imax5(&blocks, &acts);
    let mut t = Table::new(
        "Ablation 1: Q3_K 6-bit vs OP_CVT53 5-bit scales (paper: 'almost no effect')",
        &["metric", "6-bit exact", "5-bit IMAX", "delta"],
    );
    t.row(&[
        "dequant rel RMSE".into(),
        format!("{:.4}", rmse(&exact)),
        format!("{:.4}", rmse(&approx)),
        format!("{:+.4}", rmse(&approx) - rmse(&exact)),
    ]);
    t.row(&[
        "dot(16k elems)".into(),
        format!("{d_exact:.3}"),
        format!("{d_approx:.3}"),
        format!("{:+.2}%", 100.0 * (d_approx - d_exact) / d_exact.abs().max(1e-6)),
    ]);
    t.print();

    // --- Ablation 2: LMM capacity sweep (LOAD amplification).
    println!();
    let mut t = Table::new(
        "Ablation 2: LOAD bytes vs LMM capacity (mul_mat 1280x4096x1280, Q8_0)",
        &["LMM", "act tiles", "w tiles", "DMA load", "amplification"],
    );
    let (m, nn, k) = (1280usize, 4096usize, 1280usize);
    let base = {
        let mut cfg = ImaxConfig::fpga(1);
        cfg.lmm_bytes = usize::MAX / 2;
        TilePlan::new(&cfg, KernelKind::Q8_0, m, nn, k).unwrap().load_bytes()
    };
    for kb in [128usize, 256, 512, 1024, 4096] {
        let mut cfg = ImaxConfig::fpga(1);
        cfg.lmm_bytes = kb * 1024;
        match TilePlan::new(&cfg, KernelKind::Q8_0, m, nn, k) {
            Ok(p) => {
                t.row(&[
                    format!("{kb} KiB"),
                    format!("{}", p.a_tiles()),
                    format!("{}", p.w_tiles()),
                    imax_sd::util::stats::fmt_bytes(p.load_bytes() as f64),
                    format!("{:.2}x", p.load_bytes() as f64 / base as f64),
                ]);
            }
            Err(_) => {
                t.row(&[format!("{kb} KiB"), "-".into(), "-".into(), "OOM".into(), "-".into()]);
            }
        }
    }
    t.print();

    // --- Ablation 3: kernel-mapping geometry.
    println!();
    let mut t = Table::new(
        "Ablation 3: kernel mapping geometry (EXEC efficiency)",
        &["kernel", "PEs", "MACs/beat", "EXEC cyc (64x64x4096)", "cyc/MAC"],
    );
    for kind in [KernelKind::Q8_0, KernelKind::Q3K] {
        let cfg = KernelConfig::for_kind(kind);
        let lane = LaneSim::new(ImaxConfig::fpga(1));
        let bd = lane.analytic_mul_mat(kind, 64, 64, 4096, true).unwrap();
        let macs = (64 * 64 * 4096) as f64;
        t.row(&[
            kind.name().into(),
            format!("{}", cfg.pe_count()),
            format!("{}", cfg.macs_per_beat()),
            format!("{}", bd.exec),
            format!("{:.3}", bd.exec as f64 / macs),
        ]);
    }
    t.print();
}
