//! Regenerates **Table II**: physical specifications of the evaluated
//! hardware platforms (static spec data + the IMAX power model's two
//! published synthesis points).

use imax_sd::device::table2_specs;
use imax_sd::imax::power::{asic_power_units, ASIC_BASE_WATTS, ASIC_WATTS_PER_UNIT};
use imax_sd::util::tables::Table;

fn main() {
    let mut t = Table::new(
        "TABLE II: Physical specifications of evaluated hardware platforms",
        &["Device", "Host CPU", "Cores", "Area mm2", "Process", "Frequency", "Memory", "Power (W)"],
    );
    for r in table2_specs() {
        t.row_str(&[r.device, r.host, r.cores, r.area_mm2, r.process, r.frequency, r.memory, r.power]);
    }
    t.print();
    println!(
        "\nIMAX 28nm power model: P(units) = {ASIC_BASE_WATTS:.2} + units x {ASIC_WATTS_PER_UNIT:.2} W \
         -> Q8_0/46u = {:.1} W, Q3_K/51u = {:.1} W (paper: 47.7 / 52.8)",
        asic_power_units(46),
        asic_power_units(51),
    );
}
