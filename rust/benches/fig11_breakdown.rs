//! Regenerates **Fig. 11**: IMAX processing-time breakdown
//! (EXEC/LOAD/DRAIN/CONF/REGV/RANGE) for the Q3_K and Q8_0 kernels on
//! the FPGA prototype.
//!
//! Paper shape: LOAD dominates both kernels; Q8_0's transfer volume
//! (8.5 b/w vs 3.4375) makes its LOAD share larger.

use imax_sd::device::ImaxDevice;
use imax_sd::sd::arch::sd_turbo_512;
use imax_sd::sd::QuantModel;
use imax_sd::util::tables::StackedBars;

fn main() {
    let trace = sd_turbo_512(1);
    let dev = ImaxDevice::fpga(1);
    let mut sb = StackedBars::new(
        "Fig. 11: IMAX FPGA processing time breakdown (s)",
        "s",
        &["EXEC", "LOAD", "DRAIN", "CONF", "REGV", "RANGE"],
    );
    for model in [QuantModel::Q3K, QuantModel::Q8_0] {
        let p = dev.offload_phase_seconds(&trace, model);
        sb.bar(model.name(), &p.fig11_order());
        println!(
            "{:>5}: EXEC {:.2}s LOAD {:.2}s DRAIN {:.2}s CONF {:.4}s REGV {:.3}s RANGE {:.3}s  total {:.2}s",
            model.name(), p.exec, p.load, p.drain, p.conf, p.regv, p.range, p.total()
        );
    }
    println!();
    sb.print();
    println!("\npaper shape: LOAD-dominated; Q8_0 LOAD > Q3_K LOAD");
}
