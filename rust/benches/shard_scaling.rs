//! Shard scaling: single-op row-tile sharding across 1–8 lanes.
//!
//! Replays the mini U-Net denoising step through a `ShardedBackend` and
//! reports, per lane count:
//!
//! * **kernel seconds** — the slowest lane's simulated cycles per step
//!   over the 145 MHz FPGA clock (lanes run their shards in parallel, so
//!   the max-lane time is the step's lane wall-clock);
//! * **warm weight LOAD B/lane** — the max per-lane DMA *weight* bytes
//!   of a warm step: the ROADMAP's bandwidth-scaling claim is that this
//!   shrinks as lanes are added, because each lane caches (and pins)
//!   only its own row-tile shards and the aggregate resident bytes grow
//!   with the lane count.
//!
//! A third section measures **host wall-clock** of the lane worker
//! pool: the same op stream submitted asynchronously over 1 vs 4 lanes
//! (`--threads > 1` enables the pool; shards of an op then execute
//! concurrently on their lanes' worker threads). Multi-lane wall-clock
//! must come in strictly below single-lane — the simulated counters are
//! bit-identical either way, so this is pure execution overlap.
//!
//! The simulated numbers are deterministic; the wall-clock section is
//! host-dependent by nature. `--smoke` shrinks the sweep for CI;
//! `--threads N` sets the host thread count (default 4);
//! `--conv-offload on` additionally row-tile-shards the F16
//! `ConvIm2col` weights across the lanes (the §VI OP_SML16 datapath);
//! the warm per-lane LOAD and kernel-seconds monotonicity holds in
//! both modes (`python/replica/conv_offload_replica.py` replays the
//! conv-on sweep step by step).

use imax_sd::coordinator::OffloadPolicy;
use imax_sd::imax::ImaxConfig;
use imax_sd::sd::plan::{replay_unet_steps_sharded_policy, ShardStepCost};
use imax_sd::sd::QuantModel;
use imax_sd::util::tables::Table;

fn wall_clock_section(threads: usize, smoke: bool) {
    use imax_sd::ggml::{DType, Tensor, WeightId};
    use imax_sd::sd::backend::{ExecBackend, OpDesc, ShardedBackend};
    use imax_sd::util::rng::Xoshiro256pp;

    let (m, k, n) = (512usize, 512usize, 64usize);
    let n_ops = if smoke { 4 } else { 8 };
    let reps = if smoke { 1 } else { 2 };
    let mk = |rows: usize, cols: usize, seed: u64| {
        let mut r = Xoshiro256pp::seed_from_u64(seed);
        let mut v = vec![0.0f32; rows * cols];
        r.fill_normal(&mut v, 0.5);
        Tensor::f32(rows, cols, v)
    };
    let ws: Vec<Tensor> = (0..n_ops)
        .map(|i| mk(m, k, 900 + i as u64).quantize(DType::Q8_0).with_wid(WeightId(900 + i as u64)))
        .collect();
    let xs: Vec<Tensor> = (0..n_ops).map(|i| mk(n, k, 950 + i as u64)).collect();

    let mut t = Table::new(
        &format!(
            "Parallel wall-clock: {n_ops} x ({m}x{k} . {n}x{k}) Q8_0 stream, \
             {threads} host threads, best of 3"
        ),
        &["lanes", "wall ms", "speedup"],
    );
    let mut wall_by_lanes = Vec::new();
    for lanes in [1usize, 4] {
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let mut b = ShardedBackend::from_config(ImaxConfig::fpga(lanes), threads);
            let t0 = std::time::Instant::now();
            for _ in 0..reps {
                // Submit the whole wave before syncing any op: with the
                // pool enabled the shards overlap across lane workers.
                let handles: Vec<_> =
                    ws.iter().zip(&xs).map(|(w, x)| b.submit(OpDesc::linear(w, x))).collect();
                for h in handles {
                    std::hint::black_box(b.sync(h));
                }
            }
            best = best.min(t0.elapsed().as_secs_f64());
        }
        wall_by_lanes.push((lanes, best));
    }
    let single = wall_by_lanes[0].1;
    for &(lanes, s) in &wall_by_lanes {
        t.row(&[format!("{lanes}"), format!("{:.1}", s * 1e3), format!("{:.2}x", single / s)]);
    }
    t.print();
    if threads > 1 {
        let (lanes, multi) = wall_by_lanes[1];
        assert!(
            multi < single,
            "{lanes}-lane wall-clock must beat single-lane with the worker pool on \
             ({multi:.3}s vs {single:.3}s)"
        );
    } else {
        println!("(--threads 1: pool disabled, no wall-clock assertion)");
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let threads = args
        .iter()
        .position(|a| a == "--threads")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(4usize);
    let conv_offload = args
        .iter()
        .position(|a| a == "--conv-offload")
        .and_then(|i| args.get(i + 1))
        .map(|v| v == "on")
        .unwrap_or(false);
    let policy =
        if conv_offload { OffloadPolicy::QuantizedAndConv } else { OffloadPolicy::QuantizedOnly };
    let lane_sweep: &[usize] = if smoke { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    let clock_hz = ImaxConfig::fpga(1).clock_hz;
    println!(
        "shard_scaling: mini U-Net step, row-tile sharding over {:?} lanes{} \
         (conv offload {})\n",
        lane_sweep,
        if smoke { " (smoke)" } else { "" },
        if conv_offload { "on" } else { "off" }
    );

    // 512 KiB LMM with a 64 KiB/lane cache partition: small enough that
    // no lane count holds the whole quantized weight set, so the warm
    // curve shows scaling rather than saturation.
    let (lmm, cache) = (512usize << 10, 64usize << 10);
    let mut t = Table::new(
        "Shard scaling (cold step 1, warm step 2; per-lane numbers are the max lane)",
        &[
            "model",
            "lanes",
            "cold ms",
            "warm ms",
            "cold wLOAD B/lane",
            "warm wLOAD B/lane",
            "warm hits",
        ],
    );
    for model in [QuantModel::Q8_0, QuantModel::Q3K] {
        let mut prev_warm_load: Option<u64> = None;
        let mut prev_warm_ms: Option<f64> = None;
        for &lanes in lane_sweep {
            // `threads` only selects inline vs worker-pool execution —
            // every simulated number below is bit-identical either way.
            let steps =
                replay_unet_steps_sharded_policy(model, lanes, lmm, cache, 2, threads, policy);
            let (cold, warm) = (&steps[0], &steps[1]);
            let max_w = |c: &ShardStepCost| {
                c.weight_load_per_lane.iter().max().copied().unwrap_or(0)
            };
            let ms = |cycles: u64| cycles as f64 / clock_hz * 1e3;
            let warm_ms = ms(warm.max_lane_cycles);
            t.row(&[
                model.name().to_string(),
                format!("{lanes}"),
                format!("{:.2}", ms(cold.max_lane_cycles)),
                format!("{warm_ms:.2}"),
                format!("{}", max_w(cold)),
                format!("{}", max_w(warm)),
                format!("{}", warm.hits),
            ]);
            // The acceptance regression, also asserted in
            // tests/backend_equivalence.rs over 1/2/4 lanes; the conv
            // replica validates the same monotonicity with the conv
            // weights sharded in.
            if let Some(prev) = prev_warm_load {
                assert!(
                    max_w(warm) < prev,
                    "{model:?}: warm per-lane weight LOAD must shrink with lanes \
                     ({prev} B -> {} B at {lanes} lanes)",
                    max_w(warm)
                );
            }
            if let Some(prev) = prev_warm_ms {
                assert!(
                    warm_ms < prev,
                    "{model:?}: warm kernel-seconds must improve with lanes"
                );
            }
            prev_warm_load = Some(max_w(warm));
            prev_warm_ms = Some(warm_ms);
        }
    }
    t.print();
    println!(
        "\nper-lane warm weight LOAD shrinks with lanes: each lane pins only its own \
         row-tile shards, so aggregate residency scales with the lane count \
         (the cache as a bandwidth lever, not just a latency lever).\n"
    );

    wall_clock_section(threads, smoke);
}
