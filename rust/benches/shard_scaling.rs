//! Shard scaling: single-op row-tile sharding across 1–8 lanes.
//!
//! Replays the mini U-Net denoising step through a `ShardedBackend` and
//! reports, per lane count:
//!
//! * **kernel seconds** — the slowest lane's simulated cycles per step
//!   over the 145 MHz FPGA clock (lanes run their shards in parallel, so
//!   the max-lane time is the step's lane wall-clock);
//! * **warm weight LOAD B/lane** — the max per-lane DMA *weight* bytes
//!   of a warm step: the ROADMAP's bandwidth-scaling claim is that this
//!   shrinks as lanes are added, because each lane caches (and pins)
//!   only its own row-tile shards and the aggregate resident bytes grow
//!   with the lane count.
//!
//! All numbers are simulator-deterministic. `--smoke` shrinks the lane
//! sweep for CI. Results are recorded in `EXPERIMENTS.md` §Shard
//! scaling.

use imax_sd::imax::ImaxConfig;
use imax_sd::sd::plan::replay_unet_steps_sharded;
use imax_sd::sd::QuantModel;
use imax_sd::util::tables::Table;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let lane_sweep: &[usize] = if smoke { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    let clock_hz = ImaxConfig::fpga(1).clock_hz;
    println!(
        "shard_scaling: mini U-Net step, row-tile sharding over {:?} lanes{}\n",
        lane_sweep,
        if smoke { " (smoke)" } else { "" }
    );

    // 512 KiB LMM with a 64 KiB/lane cache partition: small enough that
    // no lane count holds the whole quantized weight set, so the warm
    // curve shows scaling rather than saturation.
    let (lmm, cache) = (512usize << 10, 64usize << 10);
    let mut t = Table::new(
        "Shard scaling (cold step 1, warm step 2; per-lane numbers are the max lane)",
        &[
            "model",
            "lanes",
            "cold ms",
            "warm ms",
            "cold wLOAD B/lane",
            "warm wLOAD B/lane",
            "warm hits",
        ],
    );
    for model in [QuantModel::Q8_0, QuantModel::Q3K] {
        let mut prev_warm_load: Option<u64> = None;
        let mut prev_warm_ms: Option<f64> = None;
        for &lanes in lane_sweep {
            let steps = replay_unet_steps_sharded(model, lanes, lmm, cache, 2);
            let (cold, warm) = (&steps[0], &steps[1]);
            let max_w = |c: &imax_sd::sd::plan::ShardStepCost| {
                c.weight_load_per_lane.iter().max().copied().unwrap_or(0)
            };
            let ms = |cycles: u64| cycles as f64 / clock_hz * 1e3;
            let warm_ms = ms(warm.max_lane_cycles);
            t.row(&[
                model.name().to_string(),
                format!("{lanes}"),
                format!("{:.2}", ms(cold.max_lane_cycles)),
                format!("{warm_ms:.2}"),
                format!("{}", max_w(cold)),
                format!("{}", max_w(warm)),
                format!("{}", warm.hits),
            ]);
            // The acceptance regression, also asserted in
            // tests/backend_equivalence.rs over 1/2/4 lanes.
            if let Some(prev) = prev_warm_load {
                assert!(
                    max_w(warm) < prev,
                    "{model:?}: warm per-lane weight LOAD must shrink with lanes \
                     ({prev} B -> {} B at {lanes} lanes)",
                    max_w(warm)
                );
            }
            if let Some(prev) = prev_warm_ms {
                assert!(
                    warm_ms < prev,
                    "{model:?}: warm kernel-seconds must improve with lanes"
                );
            }
            prev_warm_load = Some(max_w(warm));
            prev_warm_ms = Some(warm_ms);
        }
    }
    t.print();
    println!(
        "\nper-lane warm weight LOAD shrinks with lanes: each lane pins only its own \
         row-tile shards, so aggregate residency scales with the lane count \
         (the cache as a bandwidth lever, not just a latency lever)."
    );
}
