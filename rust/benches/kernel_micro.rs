//! Kernel micro-benchmarks (the §Perf instrument): host GGML vec-dots,
//! the IMAX functional simulator, and PJRT artifact dispatch.

use imax_sd::ggml::{q3_k, q8_0, q8_k, DType, Tensor};
use imax_sd::imax::kernels::{dot_q3_k, dot_q8_0};
use imax_sd::imax::KernelConfig;
use imax_sd::util::bench::{bench_throughput, BenchResult};
use imax_sd::util::rng::Xoshiro256pp;
use std::time::Duration;

fn random(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    let mut v = vec![0.0f32; n];
    r.fill_normal(&mut v, 0.7);
    v
}

fn main() {
    let budget = Duration::from_millis(300);
    let k = 4096usize;
    let mut results: Vec<BenchResult> = Vec::new();

    // Host quantized vec-dots (the ARM/Xeon kernel analog).
    let w8 = q8_0::quantize_row(&random(k, 1));
    let a8 = q8_0::quantize_row(&random(k, 2));
    results.push(bench_throughput("ggml q8_0 vec_dot (K=4096)", 10, budget, k as f64, || {
        std::hint::black_box(q8_0::vec_dot(&w8, &a8));
    }));

    let w3 = q3_k::quantize_row(&random(k, 3));
    let a3 = q8_k::quantize_row(&random(k, 4));
    results.push(bench_throughput("ggml q3_k vec_dot (K=4096)", 10, budget, k as f64, || {
        std::hint::black_box(q3_k::vec_dot(&w3, &a3));
    }));
    results.push(bench_throughput("ggml q3_k vec_dot imax5 (K=4096)", 10, budget, k as f64, || {
        std::hint::black_box(q3_k::vec_dot_imax5(&w3, &a3));
    }));

    // IMAX functional simulator dots.
    let c8 = KernelConfig::q8_0();
    results.push(bench_throughput("imax-sim q8_0 dot (K=4096)", 10, budget, k as f64, || {
        std::hint::black_box(dot_q8_0(&c8, &w8, &a8));
    }));
    let c3 = KernelConfig::q3_k();
    results.push(bench_throughput("imax-sim q3_k dot (K=4096)", 10, budget, k as f64, || {
        std::hint::black_box(dot_q3_k(&c3, &w3, &a3));
    }));

    // Quantization (the host marshalling cost).
    let acts = random(k, 5);
    results.push(bench_throughput("quantize_row q8_0 (K=4096)", 10, budget, k as f64, || {
        std::hint::black_box(q8_0::quantize_row(&acts));
    }));
    results.push(bench_throughput("quantize_row q8_K (K=4096)", 10, budget, k as f64, || {
        std::hint::black_box(q8_k::quantize_row(&acts));
    }));

    // Host mul_mat across threads.
    let w = Tensor::f32(64, 1024, random(64 * 1024, 6)).quantize(DType::Q8_0);
    let x = Tensor::f32(32, 1024, random(32 * 1024, 7));
    for threads in [1usize, 2, 4] {
        let macs = (64 * 1024 * 32) as f64;
        results.push(bench_throughput(
            &format!("ggml mul_mat q8_0 64x32x1024 ({threads}t)"),
            3,
            budget,
            macs,
            || {
                std::hint::black_box(imax_sd::ggml::mul_mat(&w, &x, threads));
            },
        ));
    }

    // PJRT dispatch (feature-gated; needs the vendored xla bindings).
    pjrt_bench(&mut results, budget);

    println!("== kernel micro-benchmarks (items/s = elements or MACs) ==");
    for r in &results {
        println!("{}", r.line());
    }
}

/// PJRT artifact dispatch (when artifacts exist and `pjrt` is enabled).
#[cfg(feature = "pjrt")]
fn pjrt_bench(results: &mut Vec<BenchResult>, budget: Duration) {
    if let Some(dir) = imax_sd::runtime::find_artifact_dir() {
        let mut rt = imax_sd::runtime::ArtifactRuntime::new(dir).unwrap();
        let (m, n, kk) = (64usize, 64usize, 288usize);
        let wl = imax_sd::runtime::client::literal_f32(&random(m * kk, 8), m, kk).unwrap();
        let xl = imax_sd::runtime::client::literal_f32(&random(n * kk, 9), n, kk).unwrap();
        let exe = rt.load("f16_matmul.hlo.txt").unwrap();
        results.push(bench_throughput(
            "pjrt f16_matmul artifact 64x64x288",
            3,
            budget,
            (m * n * kk) as f64,
            || {
                std::hint::black_box(exe.run_f32(&[wl.clone(), xl.clone()]).unwrap());
            },
        ));
    }
}

/// Stub when the `pjrt` feature is off (the default, offline build).
#[cfg(not(feature = "pjrt"))]
fn pjrt_bench(_results: &mut Vec<BenchResult>, _budget: Duration) {}
