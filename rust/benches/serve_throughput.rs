//! Serving throughput: serial per-request submission vs batched
//! multi-lane submission (the `serve` subsystem's reason to exist).
//!
//! For N concurrent requests the batched path coalesces every
//! model-weight mat-mul across the micro-batch into one lane submission,
//! amortizing DMA descriptors, weight streaming and CONF/REGV/RANGE
//! configuration. Reported per mode:
//!
//! * wall-clock aggregate MAC throughput and requests/s,
//! * per-request latency (mean / p95),
//! * simulated lane efficiency: IMAX cycles per offloaded MAC
//!   (deterministic — independent of the host machine).

use imax_sd::sd::pipeline::{Backend, PipelineConfig};
use imax_sd::sd::QuantModel;
use imax_sd::serve::{ServeConfig, ServeHarness, ServeReport};
use imax_sd::util::stats::fmt_duration;
use imax_sd::util::tables::Table;

fn pipe_cfg(model: QuantModel) -> PipelineConfig {
    PipelineConfig {
        weight_seed: 0x5D_7B0,
        model: Some(model),
        steps: 1,
        backend: Backend::Host { threads: 2 },
        // The CLI default: F16 conv GEMMs coalesce and offload too.
        conv_offload: true,
    }
}

fn prompts(n: usize) -> Vec<(String, u64)> {
    (0..n).map(|i| (format!("a lovely cat wearing hat number {i}"), 42 + i as u64)).collect()
}

fn row_for(t: &mut Table, name: &str, r: &ServeReport) {
    let lat = r.latency_summary();
    t.row(&[
        name.to_string(),
        format!("{}", r.requests()),
        format!("{:.2}", r.wall_seconds),
        format!("{:.1}", r.requests_per_second()),
        format!("{:.3e}", r.macs_per_second()),
        fmt_duration(lat.mean),
        fmt_duration(lat.p95),
        fmt_duration(lat.p99),
        format!("{:.4}", r.cycles_per_offloaded_mac()),
        format!("{}", r.lane_submissions),
        format!("{}", r.batched_submissions),
    ]);
}

fn main() {
    let n_requests = 8;
    let reqs = prompts(n_requests);
    println!(
        "serve_throughput: {n_requests} concurrent requests, mini SD pipeline, Q8_0 model\n"
    );

    let mut t = Table::new(
        "Serial per-request submission vs batched multi-lane submission",
        &[
            "mode", "reqs", "wall s", "req/s", "MAC/s", "lat mean", "lat p95", "lat p99",
            "cyc/MAC", "lane subs", "merged",
        ],
    );

    let serial = ServeHarness::new(pipe_cfg(QuantModel::Q8_0), ServeConfig::serial(1, 2));
    let serial_report = serial.serve(&reqs);
    row_for(&mut t, "serial 1w/b1/1L", &serial_report);

    let batched_1l = ServeHarness::new(
        pipe_cfg(QuantModel::Q8_0),
        ServeConfig {
            lanes: 1,
            host_threads: 2,
            max_batch: 4,
            workers: 1,
            sharded: false,
            queue_capacity: 64,
        },
    );
    let batched_1l_report = batched_1l.serve(&reqs);
    row_for(&mut t, "batched 1w/b4/1L", &batched_1l_report);

    let batched_ml = ServeHarness::new(
        pipe_cfg(QuantModel::Q8_0),
        ServeConfig {
            lanes: 4,
            host_threads: 4,
            max_batch: 4,
            workers: 2,
            sharded: false,
            queue_capacity: 64,
        },
    );
    let batched_ml_report = batched_ml.serve(&reqs);
    row_for(&mut t, "batched 2w/b4/4L", &batched_ml_report);

    let sharded_ml = ServeHarness::new(
        pipe_cfg(QuantModel::Q8_0),
        ServeConfig {
            lanes: 4,
            host_threads: 4,
            max_batch: 4,
            workers: 2,
            sharded: true,
            queue_capacity: 64,
        },
    );
    let sharded_ml_report = sharded_ml.serve(&reqs);
    row_for(&mut t, "sharded 2w/b4/4L", &sharded_ml_report);

    t.print();

    let cyc_gain =
        serial_report.cycles_per_offloaded_mac() / batched_ml_report.cycles_per_offloaded_mac();
    let tp_gain = batched_ml_report.macs_per_second() / serial_report.macs_per_second();
    println!(
        "\nbatched multi-lane vs serial @ {n_requests} requests: \
         {cyc_gain:.2}x fewer simulated lane cycles per offloaded MAC, \
         {tp_gain:.2}x aggregate wall-clock MAC throughput"
    );
    assert!(
        batched_ml_report.cycles_per_offloaded_mac() < serial_report.cycles_per_offloaded_mac(),
        "batched submission must beat serial lane efficiency at >=4 concurrent requests"
    );
    assert!(
        batched_1l_report.cycles_per_offloaded_mac() < serial_report.cycles_per_offloaded_mac(),
        "the gain must come from coalescing itself, not only extra lanes/workers"
    );
    for (a, b) in batched_ml_report.outcomes.iter().zip(&sharded_ml_report.outcomes) {
        assert_eq!(
            a.image_crc32, b.image_crc32,
            "sharded lane routing must stay bit-identical to affinity routing"
        );
    }
}
