//! §VI future-work projections: the three follow-ups the paper names,
//! quantified on the calibrated substrate.
//!
//! 1. offload-ratio increase (F16 kernel) — with the prototype DMA it
//!    REGRESSES (LOAD-bound, the Fig. 11 lesson); with a production
//!    interconnect it approaches the CPU class.
//! 2. multi-core host integration — lifts the Fig. 9/10 lane ceiling.
//! 3. resolution scalability — e2e vs image size per device.

use imax_sd::device::future::ImaxFutureDevice;
use imax_sd::device::{arm_a72, xeon_w5, Device, ImaxDevice};
use imax_sd::imax::ImaxConfig;
use imax_sd::sd::arch::{clip_text_sd15, unet_sd15, vae_decoder_sd15};
use imax_sd::sd::{QuantModel, WorkloadTrace};
use imax_sd::util::tables::Table;

fn sd_at(latent: usize) -> WorkloadTrace {
    let mut t = clip_text_sd15();
    t.extend(unet_sd15(latent));
    t.extend(vae_decoder_sd15(latent));
    t
}

fn main() {
    let trace = sd_at(64);
    let m = QuantModel::Q8_0;

    // --- 1. Offload-ratio sweep.
    let mut t = Table::new(
        "Future work 1: offload ratio vs e2e (Q8_0 model, ASIC)",
        &["configuration", "offload %", "e2e (s)", "vs baseline"],
    );
    let base = ImaxDevice::asic(1).e2e_seconds(&trace, m);
    let rows: Vec<(String, f64, f64)> = vec![
        {
            let d = ImaxFutureDevice::baseline(ImaxConfig::asic(1));
            ("quantized kernels only (paper)".into(), d.offload_ratio(&trace, m), d.e2e_seconds(&trace, m))
        },
        {
            let d = ImaxFutureDevice::extended(ImaxConfig::asic(1), 2);
            ("+F16 kernel, prototype DMA".into(), d.offload_ratio(&trace, m), d.e2e_seconds(&trace, m))
        },
        {
            let mut imax = ImaxConfig::asic(1);
            imax.dma_bytes_per_cycle = 8.0;
            let d = ImaxFutureDevice::extended(imax, 2);
            ("+F16 kernel, 6.7 GB/s DMA".into(), d.offload_ratio(&trace, m), d.e2e_seconds(&trace, m))
        },
        {
            let mut imax = ImaxConfig::asic(1);
            imax.dma_bytes_per_cycle = 8.0;
            let d = ImaxFutureDevice::extended(imax, 8);
            ("+F16, fast DMA, 8-core host".into(), d.offload_ratio(&trace, m), d.e2e_seconds(&trace, m))
        },
    ];
    for (name, ratio, e2e) in rows {
        t.row(&[
            name,
            format!("{:.1}", ratio * 100.0),
            format!("{e2e:.1}"),
            format!("{:.2}x", base / e2e),
        ]);
    }
    t.print();
    println!("(Xeon reference: {:.1} s)\n", xeon_w5().e2e_seconds(&trace, m));

    // --- 1b. Conv-offload delta, both models and substrates: the same
    // experiment `benches/conv_offload.rs` runs cycle-accurately on the
    // mini U-Net, projected analytically onto the full SD-1.5 trace.
    // The F16 ops of the trace are the im2col convs, so baseline vs
    // +F16 *is* the conv-offload delta.
    let mut t = Table::new(
        "Conv-offload delta: e2e (s) without vs with the F16 conv datapath",
        &["model", "substrate", "host conv", "offload", "delta (s)", "delta"],
    );
    for model in [QuantModel::Q8_0, QuantModel::Q3K] {
        let mut fast_asic = ImaxConfig::asic(1);
        fast_asic.dma_bytes_per_cycle = 8.0;
        let subs: Vec<(&str, ImaxConfig)> = vec![
            ("FPGA, prototype DMA", ImaxConfig::fpga(1)),
            ("ASIC, prototype DMA", ImaxConfig::asic(1)),
            ("ASIC, 6.7 GB/s DMA", fast_asic),
        ];
        for (name, imax) in subs {
            let base = ImaxFutureDevice::baseline(imax.clone()).e2e_seconds(&trace, model);
            let off = ImaxFutureDevice::extended(imax, 2).e2e_seconds(&trace, model);
            t.row(&[
                model.name().to_string(),
                name.into(),
                format!("{base:.1}"),
                format!("{off:.1}"),
                format!("{:+.1}", off - base),
                format!("{:+.1}%", (off - base) / base * 100.0),
            ]);
        }
    }
    t.print();
    println!(
        "negative delta = offload wins. On the prototype DMA the conv offload\n\
         REGRESSES (im2col activation stream is LOAD-bound, Fig. 11); the\n\
         production interconnect flips the sign.\n"
    );

    // --- 2. Host-core sweep of the lane ceiling.
    let mut t = Table::new(
        "Future work 2: Q3_K kernel seconds vs lanes, by host cores (FPGA)",
        &["host cores", "1", "2", "4", "8 lanes"],
    );
    for cores in [2usize, 4, 8] {
        let mut d = ImaxFutureDevice::baseline(ImaxConfig::fpga(1));
        d.host_cores = cores;
        let mut row = vec![format!("{cores}")];
        for lanes in [1usize, 2, 4, 8] {
            row.push(format!("{:.2}", d.kernel_seconds(&trace, QuantModel::Q3K, lanes)));
        }
        t.row(&row);
    }
    t.print();
    println!();

    // --- 3. Resolution scalability (paper: "an important avenue").
    let mut t = Table::new(
        "Future work 3: e2e (s) vs image resolution (Q8_0 model)",
        &["resolution", "GMACs", "ARM", "IMAX FPGA", "IMAX ASIC", "Xeon"],
    );
    for latent in [32usize, 64, 96, 128] {
        let tr = sd_at(latent);
        t.row(&[
            format!("{}x{}", latent * 8, latent * 8),
            format!("{:.0}", tr.total_macs() as f64 / 1e9),
            format!("{:.0}", arm_a72().e2e_seconds(&tr, m)),
            format!("{:.0}", ImaxDevice::fpga(1).e2e_seconds(&tr, m)),
            format!("{:.0}", ImaxDevice::asic(1).e2e_seconds(&tr, m)),
            format!("{:.1}", xeon_w5().e2e_seconds(&tr, m)),
        ]);
    }
    t.print();
    println!("\nfinding: the FPGA-vs-ARM crossover persists at every resolution —");
    println!("transfer volume scales with the same N(tokens) as the compute.");
}
