//! Conv-offload experiment: the §VI F16 `ConvIm2col` datapath
//! (OP_SML16 kernel + LMM-tiled im2col + conv weight residency) vs the
//! paper's host-conv routing, on the mini U-Net denoising step.
//!
//! Two substrates frame the honest finding:
//!
//! * **FPGA prototype DMA** (0.193 B/cycle): offloading the F16 convs
//!   *regresses* — the conv activation stream is LOAD-bound, the
//!   Fig. 11 lesson (also asserted by `device::future`).
//! * **ASIC + production interconnect** (6.7 GB/s DMA, LMM big enough
//!   to hold the conv + quantized weight sets): warm steps beat both
//!   the cold offload step and the host-conv path — the same
//!   inequalities `tests/weight_cache.rs` asserts and
//!   `python/replica/conv_offload_replica.py` replicates.
//!
//! `--conv-offload off` replays only the host-conv (QuantizedOnly)
//! routing; `--threads N` drives the sharded section's lane worker
//! pool (simulated counters are bit-identical at any N); `--smoke`
//! shrinks the sweep for CI. Emits `BENCH_conv_offload.json` with the
//! cold/warm cycle and DMA-byte totals.

use imax_sd::coordinator::OffloadPolicy;
use imax_sd::device::arm_a72;
use imax_sd::imax::ImaxConfig;
use imax_sd::sd::plan::{
    replay_unet_steps_policy, replay_unet_steps_sharded_policy, unet_step_conv_macs, StepCost,
};
use imax_sd::sd::QuantModel;
use imax_sd::util::tables::Table;

struct Substrate {
    name: &'static str,
    imax: ImaxConfig,
    /// Whether the warm offload step must beat the host-conv path here
    /// (true on the production interconnect, false on the prototype
    /// DMA, where the offload legitimately regresses).
    offload_wins: bool,
}

fn substrates() -> Vec<Substrate> {
    let mut asic = ImaxConfig::asic(1);
    asic.lmm_bytes = 8 << 20;
    asic.weight_cache_bytes = 4 << 20;
    asic.dma_bytes_per_cycle = 8.0; // §VI production interconnect
    vec![
        Substrate {
            name: "FPGA 145MHz, prototype DMA",
            imax: ImaxConfig::fpga(1),
            offload_wins: false,
        },
        Substrate { name: "ASIC 840MHz, 6.7GB/s DMA, 8M LMM", imax: asic, offload_wins: true },
    ]
}

// `offload_wins` also gates the warm-vs-cold assertion: it only holds
// where the cache pins the whole conv weight set (see main()).

/// One JSON record per (model, substrate) pair.
struct Record {
    model: &'static str,
    substrate: &'static str,
    conv_offload: bool,
    cold: StepCost,
    warm: StepCost,
    host_path_cycles: u64,
}

fn emit_json(records: &[Record]) {
    let mut s = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"model\": \"{}\", \"substrate\": \"{}\", \"conv_offload\": {}, \
             \"cold_cycles\": {}, \"warm_cycles\": {}, \
             \"cold_load_bytes\": {}, \"warm_load_bytes\": {}, \
             \"warm_hits\": {}, \"warm_hit_bytes\": {}, \
             \"host_conv_path_cycles\": {}}}{}\n",
            r.model,
            r.substrate,
            r.conv_offload,
            r.cold.cycles,
            r.warm.cycles,
            r.cold.load_bytes,
            r.warm.load_bytes,
            r.warm.hits,
            r.warm.hit_bytes,
            r.host_path_cycles,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    s.push_str("]\n");
    let path = "BENCH_conv_offload.json";
    std::fs::write(path, s).expect("write bench json");
    println!("wrote {path} ({} records)", records.len());
}

fn sharded_section(threads: usize, smoke: bool) {
    let lane_sweep: &[usize] = if smoke { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    let (lmm, cache) = (512usize << 10, 64usize << 10);
    let clock_hz = ImaxConfig::fpga(1).clock_hz;
    let mut t = Table::new(
        &format!(
            "Sharded conv offload (FPGA, {} KiB LMM, {} KiB cache/lane, {threads} host threads)",
            lmm >> 10,
            cache >> 10
        ),
        &["model", "lanes", "cold ms", "warm ms", "cold wLOAD B/lane", "warm wLOAD B/lane"],
    );
    for model in [QuantModel::Q8_0, QuantModel::Q3K] {
        let mut prev_warm_load: Option<u64> = None;
        let mut prev_warm_cyc: Option<u64> = None;
        for &lanes in lane_sweep {
            let steps = replay_unet_steps_sharded_policy(
                model,
                lanes,
                lmm,
                cache,
                2,
                threads,
                OffloadPolicy::QuantizedAndConv,
            );
            let (cold, warm) = (&steps[0], &steps[1]);
            let max_w = |c: &imax_sd::sd::plan::ShardStepCost| {
                c.weight_load_per_lane.iter().max().copied().unwrap_or(0)
            };
            let ms = |cycles: u64| cycles as f64 / clock_hz * 1e3;
            t.row(&[
                model.name().to_string(),
                format!("{lanes}"),
                format!("{:.2}", ms(cold.max_lane_cycles)),
                format!("{:.2}", ms(warm.max_lane_cycles)),
                format!("{}", max_w(cold)),
                format!("{}", max_w(warm)),
            ]);
            // Warm-vs-cold is NOT claimed here: the 64 KiB/lane budget
            // pins only a slice of the conv weight set, and shards that
            // cached transiently during the cold step re-stream every
            // warm step (the replica shows warm > cold per lane). What
            // does hold — and what the ROADMAP bandwidth claim needs —
            // is the monotone warm shrink as lanes are added.
            if let Some(prev) = prev_warm_load {
                assert!(
                    max_w(warm) < prev,
                    "{model:?}: warm per-lane weight LOAD must shrink with lanes \
                     ({prev} B -> {} B at {lanes} lanes)",
                    max_w(warm)
                );
            }
            if let Some(prev) = prev_warm_cyc {
                assert!(
                    warm.max_lane_cycles < prev,
                    "{model:?}: warm lane wall-clock must improve with lanes"
                );
            }
            prev_warm_load = Some(max_w(warm));
            prev_warm_cyc = Some(warm.max_lane_cycles);
        }
    }
    t.print();
    println!(
        "\nper-lane conv weight LOAD shrinks with lanes: row-tile shards of the F16 conv\n\
         weights pin per lane, and the im2col activation stream is broadcast-elided\n\
         (tests/shard_props.rs asserts the byte invariance per op).\n"
    );
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let threads = args
        .iter()
        .position(|a| a == "--threads")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(4usize);
    let conv_offload = args
        .iter()
        .position(|a| a == "--conv-offload")
        .and_then(|i| args.get(i + 1))
        .map(|v| v != "off")
        .unwrap_or(true);
    let steps = if smoke { 2 } else { 3 };
    println!(
        "conv_offload: mini U-Net step, F16 ConvIm2col via OP_SML16 (conv offload {}{})\n",
        if conv_offload { "on" } else { "off" },
        if smoke { ", smoke" } else { "" }
    );

    let mut t = Table::new(
        "Conv offload vs host-conv path (cold step 1, warm step 2)",
        &[
            "model",
            "substrate",
            "mode",
            "cold Mcyc",
            "warm Mcyc",
            "warm LOAD B",
            "host path Mcyc",
            "warm/host",
        ],
    );
    let mut records = Vec::new();
    for model in [QuantModel::Q8_0, QuantModel::Q3K] {
        let conv_macs = unet_step_conv_macs(model);
        assert!(conv_macs > 100_000_000, "convs dominate the step ({conv_macs} MACs)");
        for sub in substrates() {
            let policy =
                if conv_offload { OffloadPolicy::QuantizedAndConv } else { OffloadPolicy::QuantizedOnly };
            let run = replay_unet_steps_policy(model, sub.imax.clone(), steps, policy);
            let quant =
                replay_unet_steps_policy(model, sub.imax.clone(), steps, OffloadPolicy::QuantizedOnly);
            let (cold, warm) = (run[0], run[1]);
            // Host-conv path: quantized-only lane cycles plus the conv
            // MACs at the A72's F16 rate, in lane-clock cycles.
            let host_conv_cycles =
                (conv_macs as f64 / (arm_a72().gmacs_f16 * 1e9) * sub.imax.clock_hz) as u64;
            let host_path = quant[1].cycles + host_conv_cycles;
            let mcyc = |c: u64| format!("{:.2}", c as f64 / 1e6);
            t.row(&[
                model.name().to_string(),
                sub.name.into(),
                if conv_offload { "offload".into() } else { "host conv".to_string() },
                mcyc(cold.cycles),
                mcyc(warm.cycles),
                format!("{}", warm.load_bytes),
                mcyc(host_path),
                format!("{:.2}x", warm.cycles as f64 / host_path as f64),
            ]);
            if conv_offload {
                if sub.offload_wins {
                    // On the 256 KiB FPGA cache the pin pass locks the
                    // budget and mid-sized conv weights that cached
                    // transiently during the cold step re-stream every
                    // warm chunk, so cold-vs-warm is only a claim where
                    // the weight set actually fits (the substrate
                    // tests/weight_cache.rs pins the inequality on).
                    assert!(
                        warm.cycles < cold.cycles,
                        "{model:?} on {}: resident conv weights must beat the cold step",
                        sub.name
                    );
                }
                if !smoke {
                    assert_eq!(run[1], run[2], "{model:?} on {}: steady state", sub.name);
                }
                if sub.offload_wins {
                    assert!(
                        warm.cycles < host_path,
                        "{model:?} on {}: warm offload ({}) must beat the host-conv \
                         path ({host_path})",
                        sub.name,
                        warm.cycles
                    );
                } else {
                    // The Fig. 11 lesson, stated positively: on the
                    // prototype DMA the conv stream is LOAD-bound and
                    // the offload loses to the host-conv path.
                    assert!(
                        warm.cycles > host_path,
                        "{model:?} on {}: the prototype-DMA regression disappeared? \
                         ({} vs {host_path})",
                        sub.name,
                        warm.cycles
                    );
                }
            }
            records.push(Record {
                model: model.name(),
                substrate: sub.name,
                conv_offload,
                cold,
                warm,
                host_path_cycles: host_path,
            });
        }
    }
    t.print();
    println!(
        "\nhost path = quantized-only warm lane cycles + conv MACs at the A72 F16 rate\n\
         ({:.1} GMAC/s), in lane-clock cycles. The offload wins only with the production\n\
         interconnect — on the prototype DMA it regresses (the Fig. 11 lesson).\n",
        arm_a72().gmacs_f16
    );

    if conv_offload {
        sharded_section(threads, smoke);
    } else {
        println!("(--conv-offload off: sharded conv section skipped)");
    }
    emit_json(&records);
}
