//! Cold-step vs warm-step lane cost under weight residency.
//!
//! Replays the mini U-Net denoising step on one simulated lane and
//! reports, per step, the simulated lane cycles and DMA LOAD bytes —
//! cold (step 1: every weight misses and is DMA'd) vs warm (steps ≥ 2:
//! resident weights skip LOAD entirely). Run for both quantized models
//! and two LMM shapes:
//!
//! * `fpga 512K/256K` — the paper's 512 KiB LMM with half reserved as
//!   cache: only the plan-pinned hottest weights stay resident;
//! * `roomy 4M/2M` — a cache that holds the full weight set: warm steps
//!   move activations only.
//!
//! All reported numbers are simulator-deterministic (independent of the
//! host machine). `--smoke` shrinks the step count for CI.

use imax_sd::sd::plan::replay_unet_steps;
use imax_sd::sd::QuantModel;
use imax_sd::util::tables::Table;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let steps = if smoke { 2 } else { 4 };
    println!(
        "weight_reuse: mini U-Net denoising steps on one lane, {} steps{}\n",
        steps,
        if smoke { " (smoke)" } else { "" }
    );

    let mut t = Table::new(
        "Cold vs warm denoising steps (simulated lane)",
        &["model", "LMM / cache", "step", "cycles", "LOAD B", "hits", "hit B"],
    );
    let shapes: [(&str, usize, usize); 3] = [
        ("512K / off", 512 << 10, 0),
        ("512K / 256K", 512 << 10, 256 << 10),
        ("4M / 2M", 4 << 20, 2 << 20),
    ];
    for model in [QuantModel::Q8_0, QuantModel::Q3K] {
        for (label, lmm, cache) in shapes {
            let costs = replay_unet_steps(model, lmm, cache, steps);
            for (i, c) in costs.iter().enumerate() {
                t.row(&[
                    model.name().to_string(),
                    label.to_string(),
                    format!("{}", i + 1),
                    format!("{}", c.cycles),
                    format!("{}", c.load_bytes),
                    format!("{}", c.hits),
                    format!("{}", c.hit_bytes),
                ]);
            }
            let (cold, warm) = (&costs[0], &costs[costs.len() - 1]);
            println!(
                "{} {label}: warm/cold cycles {:.3}, warm/cold LOAD bytes {:.3}",
                model.name(),
                warm.cycles as f64 / cold.cycles as f64,
                warm.load_bytes as f64 / cold.load_bytes as f64,
            );
            if cache > 0 {
                assert!(
                    warm.cycles < cold.cycles,
                    "{model:?} {label}: warm step must be strictly cheaper"
                );
            }
        }
    }
    println!();
    t.print();
}
