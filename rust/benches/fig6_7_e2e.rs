//! Regenerates **Fig. 6** (Q3_K) and **Fig. 7** (Q8_0): end-to-end
//! latency for one 512×512 SD-Turbo generation on every device.
//!
//! Paper anchors: Fig.6 ARM 809.7 / FPGA 790.3 / ASIC 754.5 / Xeon 59.3 /
//! GPU 16.2 s. Fig.7 ARM 625.1 / FPGA 654.7 / ASIC 558.0 s — note the
//! crossover: the FPGA *loses* to standalone ARM on Q8_0 (transfer
//! volume), the paper's central finding.

use imax_sd::device::future::ImaxFutureDevice;
use imax_sd::device::{arm_a72, gtx_1080ti, xeon_w5, Device, ImaxDevice};
use imax_sd::imax::ImaxConfig;
use imax_sd::sd::arch::sd_turbo_512;
use imax_sd::sd::QuantModel;
use imax_sd::util::tables::BarChart;

fn main() {
    let trace = sd_turbo_512(1);
    for (fig, model) in [(6, QuantModel::Q3K), (7, QuantModel::Q8_0)] {
        let devices: Vec<(String, f64)> = vec![
            ("ARM Cortex-A72".into(), arm_a72().e2e_seconds(&trace, model)),
            ("IMAX3 FPGA 145MHz".into(), ImaxDevice::fpga(1).e2e_seconds(&trace, model)),
            ("IMAX3 ASIC 840MHz".into(), ImaxDevice::asic(1).e2e_seconds(&trace, model)),
            ("Xeon w5-2465X".into(), xeon_w5().e2e_seconds(&trace, model)),
            ("GTX 1080 Ti".into(), gtx_1080ti().e2e_seconds(&trace, model)),
        ];
        let mut c = BarChart::new(
            &format!("Fig. {fig}: E2E latency, {} model inference (s)", model.name()),
            "s",
        )
        .log();
        for (name, secs) in &devices {
            c.bar(name, *secs);
        }
        c.print();
        println!();
    }
    println!("paper anchors: Fig6 809.7/790.3/754.5/59.3/16.2  Fig7 625.1/654.7/558.0/~60/~15");

    // Projected conv-offload delta on these same bars: the F16 ops of
    // the trace are the im2col convs, so ImaxFutureDevice baseline vs
    // extended is exactly the F16 conv datapath delta
    // (`benches/conv_offload.rs` measures it cycle-accurately on the
    // mini U-Net; `benches/future_work.rs` sweeps the substrates).
    println!("\nprojected conv-offload delta on the ASIC bars (F16 kernel):");
    for (fig, model) in [(6, QuantModel::Q3K), (7, QuantModel::Q8_0)] {
        let base = ImaxFutureDevice::baseline(ImaxConfig::asic(1)).e2e_seconds(&trace, model);
        let proto = ImaxFutureDevice::extended(ImaxConfig::asic(1), 2).e2e_seconds(&trace, model);
        let mut fast = ImaxConfig::asic(1);
        fast.dma_bytes_per_cycle = 8.0;
        let prod = ImaxFutureDevice::extended(fast, 2).e2e_seconds(&trace, model);
        println!(
            "  Fig.{fig} {}: {base:.1} s -> {proto:.1} s on the prototype DMA \
             ({:+.0}%, regression), {prod:.1} s with 6.7 GB/s DMA ({:+.0}%)",
            model.name(),
            (proto - base) / base * 100.0,
            (prod - base) / base * 100.0,
        );
    }
}
