//! Regenerates **Fig. 8**: Power-Delay Product per device, both models.
//!
//! Paper findings to reproduce: ARM lowest; IMAX-ASIC beats Xeon on both
//! models; IMAX-ASIC beats the GPU on Q3_K.

use imax_sd::device::{arm_a72, gtx_1080ti, pdp_joules, xeon_w5, Device, ImaxDevice};
use imax_sd::sd::arch::sd_turbo_512;
use imax_sd::sd::QuantModel;
use imax_sd::util::tables::BarChart;

fn main() {
    let trace = sd_turbo_512(1);
    for model in [QuantModel::Q3K, QuantModel::Q8_0] {
        let mut c = BarChart::new(
            &format!("Fig. 8 ({} model): PDP = phase-weighted energy (J)", model.name()),
            "J",
        )
        .log();
        let devs: Vec<Box<dyn Device>> = vec![
            Box::new(arm_a72()),
            Box::new(ImaxDevice::fpga(1)),
            Box::new(ImaxDevice::asic(1)),
            Box::new(xeon_w5()),
            Box::new(gtx_1080ti()),
        ];
        for d in &devs {
            let e = pdp_joules(d.as_ref(), &trace, model);
            c.bar(&e.device, e.joules);
        }
        c.print();
        println!();
    }
    println!("paper shape: ARM lowest; ASIC < Xeon (both); ASIC < GPU (Q3_K)");
}
