//! Regenerates **Table I**: breakdown of dot-product execution time by
//! quantized type for the Q3_K and Q8_0 models.
//!
//! The paper profiles stable-diffusion.cpp's mat-mul kernels ("pure
//! computation time with memory copy overhead excluded"); we price the
//! reconstructed SD-Turbo 512×512 trace on the calibrated Xeon model
//! (see DESIGN.md §Calibration).

use imax_sd::device::baseline::xeon_w5;
use imax_sd::sd::arch::sd_turbo_512;
use imax_sd::sd::profiler::{paper_table1, table1_shares};
use imax_sd::sd::QuantModel;
use imax_sd::util::tables::Table;

fn main() {
    let trace = sd_turbo_512(1);
    let dev = xeon_w5();
    let mut t = Table::new(
        "TABLE I: Breakdown of execution time in dot-product kernel (% of dot time)",
        &["Model", "F32", "F16", "Q3_K", "Q8_0"],
    );
    for model in [QuantModel::Q3K, QuantModel::Q8_0] {
        let shares = table1_shares(&trace, &dev, model);
        let get = |n: &str| {
            shares
                .iter()
                .find(|(m, _)| *m == n)
                .map(|(_, v)| format!("{v:.1} %"))
                .unwrap_or_else(|| "-".into())
        };
        t.row(&[
            format!("{} Model (ours)", model.name()),
            get("F32"),
            get("F16"),
            get("Q3_K"),
            get("Q8_0"),
        ]);
        let paper = paper_table1(model);
        let pget = |n: &str| {
            paper
                .iter()
                .find(|(m, _)| *m == n)
                .map(|(_, v)| format!("{v:.1} %"))
                .unwrap_or_else(|| "-".into())
        };
        t.row(&[
            format!("{} Model (paper)", model.name()),
            pget("F32"),
            pget("F16"),
            pget("Q3_K"),
            pget("Q8_0"),
        ]);
    }
    t.print();
    println!(
        "\noffload ratio (MACs): Q3_K {:.1} %, Q8_0 {:.1} %  (paper: \"less than 20 %\")",
        100.0 * trace.offloaded_macs(QuantModel::Q3K) as f64 / trace.total_macs() as f64,
        100.0 * trace.offloaded_macs(QuantModel::Q8_0) as f64 / trace.total_macs() as f64,
    );
}
