"""Python replica of the shard-scaling experiment (no Rust toolchain needed).

Re-implements, in deterministic integer math, exactly what
``benches/shard_scaling.rs`` measures through the Rust simulator:

* the mini U-Net's quantized op list (dispatch order, shapes, WeightIds
  minted like ``WeightFactory::weight_id`` with seed 1),
* the sharded prefetch/pin pass (``Coordinator::apply_plan_sharded``:
  hottest-first, ``ShardPlan`` row partition, per-lane budgets),
* the shard geometry of ``Coordinator::shard_geometry``: rows capped to
  the per-lane cache budget, floored by the cycle-model shard threshold
  (``min_shard_rows``), shard ``i`` on lane ``(wid % lanes + i) %
  lanes``,
* per-shard execution on per-lane LMM caches (lookup/insert/LRU with
  pins, ``TilePlan`` over the transient partition, the
  ``breakdown_for_plan_with_residency`` phase pricing and DMA byte
  accounting of ``imax/lane.rs``), including **activation broadcast
  elision**: only shard 0 of an op charges the activation LOAD bytes
  (``LaneSim::set_act_byte_elision`` — bytes only, cycles unchanged).

The lane worker pool (``--threads > 1``) never changes these numbers —
that is the determinism contract — so one replica backs both the
sequential and the parallel execution mode. The ``ideal overlap``
column (total lane cycles / slowest lane's cycles) is the upper bound
on the parallel speedup the pool can realize on a step.

Running it prints the tables recorded in ``EXPERIMENTS.md`` §Shard
scaling and asserts the same monotonicity the bench asserts, so the
recorded numbers and the CI smoke run measure one definition.
"""

import math

MASK = (1 << 64) - 1

# --- ImaxConfig::fpga -------------------------------------------------------
CLOCK_HZ = 145.0e6
DMA_BPC = 0.193
DMA_SETUP = 4_000
CONF_PER_PE = 16
REGV_PER_PE = 4
RANGE_PER_PE = 4

KCFG = {
    # kind: (pe_count, elems_per_beat, groups, pipeline_depth)
    "Q8_0": (46, 32, 3, 16),
    "Q3_K": (51, 16, 3, 18),
}


def fnv1a64(data: bytes) -> int:
    h = 0xCBF29CE484222325
    for b in data:
        h = ((h ^ b) * 0x100000001B3) & MASK
    return h


def rotl(x: int, n: int) -> int:
    return ((x << n) | (x >> (64 - n))) & MASK


def weight_id(seed: int, name: str, dtype: str) -> int:
    # WeightFactory::weight_id
    return (
        fnv1a64(name.encode())
        ^ ((seed * 0x9E3779B97F4A7C15) & MASK)
        ^ rotl(fnv1a64(dtype.encode()), 32)
    )


def shard_wid(parent: int, index: int, count: int) -> int:
    # coordinator::shard::shard_wid
    if count == 1:
        return parent
    h = parent ^ 0xA0761D6478BD642F
    h = (h * 0x100000001B3) & MASK
    h ^= ((index << 32) | count) & MASK
    h = (h * 0x9E3779B97F4A7C15) & MASK
    return h


def w_row_bytes(kind: str, k: int) -> int:
    return k // 32 * 34 if kind == "Q8_0" else k // 256 * 110


def a_row_bytes(kind: str, k: int) -> int:
    return k // 32 * 34 if kind == "Q8_0" else k // 256 * (4 + 256 + 2 * 16)


def transfer(bytes_: int) -> int:
    if bytes_ == 0:
        return 0
    return DMA_SETUP + math.ceil(bytes_ / DMA_BPC)


def beats_for_dot(kind: str, k: int) -> int:
    _, elems, groups, _ = KCFG[kind]
    nb = -(-k // elems)
    return -(-nb // groups)


def min_shard_rows(kind: str, k: int, n: int) -> int:
    # Coordinator::min_shard_rows: the per-row work must amortize the
    # fixed per-shard cost (3 DMA setups + per-PE REGV/RANGE/CONF) 4x.
    pe = KCFG[kind][0]
    fixed = 3 * DMA_SETUP + (REGV_PER_PE + RANGE_PER_PE + CONF_PER_PE) * pe
    stream = lambda b: math.ceil(b / DMA_BPC)
    row_cycles = (n * (beats_for_dot(kind, k) + 2)
                  + stream(w_row_bytes(kind, k)) + stream(n * 4))
    return -(-(4 * fixed) // max(row_cycles, 1))


def tile_plan(capacity: int, kind: str, m: int, n: int, k: int):
    # TilePlan::with_capacity
    wrb, arb = w_row_bytes(kind, k), a_row_bytes(kind, k)
    a_tile = min(max(min(capacity // 2 // arb, max(n, 1)), 1), n)
    while True:
        a_bytes = a_tile * arb
        if a_bytes <= capacity:
            rem = capacity - a_bytes
            per_w_row = wrb + a_tile * 4
            if rem >= per_w_row:
                return dict(m=m, n=n, k=k, a_tile=a_tile,
                            w_tile=min(rem // per_w_row, m), wrb=wrb, arb=arb)
        if a_tile == 1:
            raise MemoryError("K too large for LMM")
        a_tile //= 2


def breakdown(kind: str, plan, reconf: bool, residency: str):
    # breakdown_for_plan_with_residency; returns (cycles, act_load_B, w_load_B)
    pe, _, _, depth = KCFG[kind]
    cyc = CONF_PER_PE * pe if reconf else 0
    w_load = plan["m"] * plan["wrb"] if residency == "Inserted" else 0
    if residency == "Inserted":
        cyc += transfer(plan["m"] * plan["wrb"])
    act_load = 0
    beats = beats_for_dot(kind, plan["k"])
    at0 = 0
    while at0 < plan["n"]:
        at1 = min(at0 + plan["a_tile"], plan["n"])
        cyc += transfer((at1 - at0) * plan["arb"])
        act_load += (at1 - at0) * plan["arb"]
        wt0 = 0
        while wt0 < plan["m"]:
            wt1 = min(wt0 + plan["w_tile"], plan["m"])
            cyc += (REGV_PER_PE + RANGE_PER_PE) * pe
            if residency == "Streamed":
                cyc += transfer((wt1 - wt0) * plan["wrb"])
                w_load += (wt1 - wt0) * plan["wrb"]
            dots = (wt1 - wt0) * (at1 - at0)
            cyc += depth + dots * (beats + 2)
            cyc += transfer(dots * 4)
            wt0 = wt1
        at0 = at1
    return cyc, act_load, w_load


class LaneCache:
    """imax/lmm.rs residency cache: LRU with pins, per-lane budget."""

    def __init__(self, budget: int):
        self.budget = budget
        self.entries = {}  # wid -> [bytes, tick, pinned]
        self.pin_wish = set()
        self.tick = 0
        self.hits = 0

    def pinned_bytes(self):
        return sum(b for b, _, p in self.entries.values() if p)

    def used(self):
        return sum(b for b, _, _ in self.entries.values())

    def lookup(self, wid, bytes_):
        self.tick += 1
        if wid in self.entries:
            self.entries[wid][1] = self.tick
            self.hits += 1
            return True
        return False

    def insert(self, wid, bytes_):
        if wid in self.entries:
            return True
        if self.budget == 0 or bytes_ > self.budget - self.pinned_bytes():
            return False
        while self.budget - self.used() < bytes_:
            victims = [(t, w) for w, (b, t, p) in self.entries.items() if not p]
            if not victims:
                return False
            del self.entries[min(victims)[1]]
        self.tick += 1
        self.entries[wid] = [bytes_, self.tick, wid in self.pin_wish]
        return True


def unet_ops(model: str):
    """Quantized op sites of one mini U-Net step, in dispatch order."""
    lin = []  # (name, m, k, n)
    lin.append(("unet.temb1", 256, 64, 1))
    lin.append(("unet.temb2", 256, 256, 1))
    lin.append(("unet.down0.emb", 64, 256, 1))
    lin.append(("unet.down1.emb", 128, 256, 1))
    tf = "unet.mid.tf"
    lin.append((f"{tf}.proj_in", 256, 128, 64))
    for a in ["attn1.q", "attn1.k", "attn1.v", "attn1.o", "attn2.q"]:
        lin.append((f"{tf}.{a}", 256, 256, 64))
    lin.append((f"{tf}.attn2.k", 256, 256, 77))
    lin.append((f"{tf}.attn2.v", 256, 256, 77))
    lin.append((f"{tf}.attn2.o", 256, 256, 64))
    lin.append((f"{tf}.ff1", 512, 256, 64))
    lin.append((f"{tf}.ff2", 256, 256, 64))
    lin.append((f"{tf}.proj_out", 128, 256, 64))
    lin.append(("unet.mid.rb.emb", 128, 256, 1))
    lin.append(("unet.up0.emb", 128, 256, 1))
    lin.append(("unet.up1.emb", 64, 256, 1))
    block = 32 if model == "Q8_0" else 256
    out = []
    for name, m, k, n in lin:
        if k % block != 0:
            continue  # WeightFactory falls back to F16 -> host path
        out.append(dict(name=name, m=m, k=k, n=n,
                        wid=weight_id(1, name, model)))
    return out


def shard_plan(m, lanes, cap, min_rows, parent):
    # coordinator::shard::ShardPlan::new — count = lanes clamped by the
    # cost-model threshold, forced up by cache-budget pressure; shard i
    # runs on lane (parent % lanes + i) % lanes.
    cap = max(cap, 1)
    by_min = max(m // max(min_rows, 1), 1)
    count = min(max(min(lanes, by_min), -(-m // cap)), m)
    base_lane = parent % lanes
    base, rem = divmod(m, count)
    shards, start = [], 0
    for i in range(count):
        ln = base + (1 if i < rem else 0)
        shards.append(dict(lane=(base_lane + i) % lanes, start=start, rows=ln,
                           wid=shard_wid(parent, i, count)))
        start += ln
    return shards


def cap_rows(row_bytes, budget, m):
    if budget == 0 or row_bytes == 0 or row_bytes > budget:
        return max(m, 1)
    return budget // row_bytes


def op_shards(model, op, lanes, budget):
    # Coordinator::shard_geometry for one dispatch site.
    rb = w_row_bytes(model, op["k"])
    return shard_plan(op["m"], lanes, cap_rows(rb, budget, op["m"]),
                      min_shard_rows(model, op["k"], op["n"]), op["wid"])


def replay(model, lanes, lmm, cache, steps):
    ops = unet_ops(model)
    budget = min(cache, lmm // 4 * 3)
    transient = lmm - budget
    caches = [LaneCache(budget) for _ in range(lanes)]
    configured = [False] * lanes
    # apply_plan_sharded: hottest-first (streamed bytes desc, wid asc);
    # the pin pass derives the same shard geometry execution will use
    # (threshold from the first recorded site's n).
    uses = {}
    for op in ops:
        wb = op["m"] * w_row_bytes(model, op["k"])
        u = uses.setdefault(op["wid"], dict(op, bytes=wb, streamed=0))
        u["streamed"] += wb
    order = sorted(uses.values(), key=lambda u: (-u["streamed"], u["wid"]))
    remaining = [budget] * lanes
    for u in order:
        rb = u["bytes"] // u["m"]
        for s in op_shards(model, u, lanes, budget):
            b = s["rows"] * rb
            if b <= remaining[s["lane"]]:
                remaining[s["lane"]] -= b
                caches[s["lane"]].pin_wish.add(s["wid"])

    results = []
    for _ in range(steps):
        cyc = [0] * lanes
        wload = [0] * lanes
        aload = [0] * lanes
        hits0 = [c.hits for c in caches]
        for op in ops:
            rb = w_row_bytes(model, op["k"])
            for i, s in enumerate(op_shards(model, op, lanes, budget)):
                lane, c = s["lane"], caches[s["lane"]]
                wb = s["rows"] * rb
                if budget > 0 and c.lookup(s["wid"], wb):
                    residency = "Resident"
                elif budget > 0 and c.insert(s["wid"], wb):
                    residency = "Inserted"
                else:
                    residency = "Streamed"
                plan = tile_plan(transient, model, s["rows"], op["n"], op["k"])
                reconf = not configured[lane]
                configured[lane] = True
                dc, da, dw = breakdown(model, plan, reconf, residency)
                cyc[lane] += dc
                wload[lane] += dw
                # Activation broadcast elision: only shard 0 charges the
                # op's activation bytes (cycles unchanged).
                aload[lane] += da if i == 0 else 0
        results.append(dict(max_ms=max(cyc) / CLOCK_HZ * 1e3,
                            total_cyc=sum(cyc),
                            max_cyc=max(cyc),
                            max_wload=max(wload),
                            act_load=sum(aload),
                            hits=sum(c.hits for c in caches) - sum(hits0)))
    return results


def main():
    lmm, cache = 512 << 10, 64 << 10
    print(f"shard_scaling replica: mini U-Net step, LMM {lmm >> 10} KiB, "
          f"cache {cache >> 10} KiB/lane\n")
    hdr = (f"{'model':6} {'lanes':>5} {'cold ms':>8} {'warm ms':>8} "
           f"{'cold wLOAD/lane':>16} {'warm wLOAD/lane':>16} {'hits':>6} "
           f"{'actLOAD B':>10} {'overlap':>8}")
    print(hdr)
    print("-" * len(hdr))
    for model in ["Q8_0", "Q3_K"]:
        total = sum(op["m"] * w_row_bytes(model, op["k"])
                    for op in unet_ops(model))
        prev_w = prev_ms = None
        act_ref = None
        for lanes in [1, 2, 4, 8]:
            cold, warm = replay(model, lanes, lmm, cache, 2)
            overlap = warm["total_cyc"] / warm["max_cyc"]
            print(f"{model:6} {lanes:>5} {cold['max_ms']:>8.2f} "
                  f"{warm['max_ms']:>8.2f} {cold['max_wload']:>16} "
                  f"{warm['max_wload']:>16} {warm['hits']:>6} "
                  f"{warm['act_load']:>10} {overlap:>7.2f}x")
            if prev_w is not None:
                assert warm["max_wload"] < prev_w, "warm wLOAD must shrink"
                assert warm["max_ms"] < prev_ms, "warm ms must shrink"
            prev_w, prev_ms = warm["max_wload"], warm["max_ms"]
            # Elision: the step's activation LOAD bytes are lane-count
            # invariant (tests/shard_props.rs asserts the same per-op).
            if act_ref is None:
                act_ref = warm["act_load"]
            assert warm["act_load"] == act_ref, "act bytes must not scale"
        print(f"{model:6} quantized weight set: {total} B\n")

    # The shard-threshold fix in isolation: tiny TimeEmbed GEMVs stay
    # single-lane, batched matmuls stay lanes-wide (the unit the Rust
    # test tiny_time_embed_gemv_stays_single_lane pins).
    print("cycle-model shard threshold (min rows/shard):")
    for kind, k, n, label in [
        ("Q8_0", 64, 1, "unet.temb1 GEMV"),
        ("Q8_0", 256, 1, "emb GEMV"),
        ("Q8_0", 256, 64, "transformer linear"),
        ("Q8_0", 128, 64, "proj_in"),
        ("Q3_K", 256, 1, "emb GEMV (Q3_K)"),
        ("Q3_K", 256, 77, "attn2.k/v (Q3_K)"),
    ]:
        print(f"  {kind} k={k:<4} n={n:<3} -> {min_shard_rows(kind, k, n):>4}"
              f"  ({label})")


if __name__ == "__main__":
    main()
