"""Python replica of the HTTP serving front-end's admission arithmetic
(no Rust toolchain needed).

Re-implements, bit-for-bit, the pure functions the serving runner uses
to decide whether a new prediction is admitted or shed
(``rust/src/server/runner.rs``):

* ``estimate_queue_seconds`` — estimated time until a newly admitted
  request would *complete*: requests ahead of it (waiting + inflight +
  itself) divided ceiling-wise into batch rounds of ``workers *
  max_batch`` slots, each round priced at the EWMA batch service time.
  Zero while the EWMA is cold (nothing measured yet — admit freely).
* ``admission_decision`` — shed with ``Retry-After =
  max(ceil(est - slo), 1)`` seconds once the estimate passes the SLO;
  a non-positive SLO disables estimate-based shedding (the bounded
  queue stays as the backstop).
* ``effective_batch_seconds`` — the cold-start admission fix: while
  the EWMA has no sample, a *busy* system (queued or inflight work)
  prices batches at a configured prior instead of zero, while an idle
  one still admits its first request freely,
* the EWMA update of ``Runner::observe_batch_seconds`` (``alpha =
  0.3``; the first observation seeds the average directly),
* ``util::stats::percentile`` — linear interpolation at rank
  ``p/100 * (len-1)`` — which ``serve/metrics.rs`` uses for the
  p50/p95/p99 the server reports and ``examples/load_gen.rs`` asserts
  against,
* the webhook retry schedule (``rust/src/server/webhook.rs``):
  ``SplitMix64`` and ``backoff_delay_ms`` — deterministic full-jitter
  exponential backoff seeded per ``(jitter_seed, prediction_id,
  attempt)`` — pinned to the exact millisecond vectors of
  ``backoff_schedule_is_pinned``.

Each function is pinned to the exact vectors of the Rust unit tests, so
a drift in either implementation fails one side's CI.

The second half runs a deterministic single-worker queueing simulation
(fixed service time, fixed arrival spacing — no randomness) twice: with
the SLO admission policy on, and with it disabled. It demonstrates the
property the load_gen bench asserts on the real server: with shedding
on, every admitted request's end-to-end latency stays within the SLO
(the estimate is a latency upper bound once the EWMA has converged,
and admission requires estimate <= SLO), while the uncontrolled queue's
tail grows without bound.
"""

import math

EWMA_ALPHA = 0.3  # runner.rs EWMA_ALPHA
MASK64 = (1 << 64) - 1
GOLDEN = 0x9E37_79B9_7F4A_7C15  # SplitMix64 increment / id mixer


def estimate_queue_seconds(waiting, inflight, workers, max_batch, ewma):
    """Mirror of ``server::runner::estimate_queue_seconds``."""
    if ewma <= 0.0:
        return 0.0
    slots = max(workers * max_batch, 1)
    ahead = waiting + inflight + 1
    rounds = -(-ahead // slots)  # usize::div_ceil
    return rounds * ewma


def admission_decision(est, slo):
    """Mirror of ``server::runner::admission_decision``.

    Returns None (admit) or the Retry-After in whole seconds (shed).
    """
    if slo <= 0.0 or est <= slo:
        return None
    return max(int(math.ceil(est - slo)), 1)


def effective_batch_seconds(ewma, prior, waiting, inflight):
    """Mirror of ``server::runner::effective_batch_seconds``."""
    if ewma > 0.0:
        return ewma
    if waiting + inflight == 0:
        return 0.0
    return prior


def splitmix64_next(state):
    """Mirror of ``util::rng::SplitMix64::next_u64``.

    Returns ``(new_state, value)`` — Python ints stand in for u64 via
    explicit 64-bit masking.
    """
    state = (state + GOLDEN) & MASK64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58_476D_1CE4_E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D0_49BB_1331_11EB) & MASK64
    return state, z ^ (z >> 31)


def backoff_delay_ms(base_ms, cap_ms, attempt, seed, prediction_id):
    """Mirror of ``server::webhook::backoff_delay_ms`` (1-based attempt)."""
    assert attempt >= 1, "attempt is 1-based"
    # saturating_mul then .min(cap): the shift exponent is clamped to 16.
    term = min(base_ms * (1 << min(attempt - 1, 16)), MASK64, cap_ms)
    half = max(term // 2, 1)
    state = seed ^ ((prediction_id * GOLDEN) & MASK64) ^ attempt
    _, draw = splitmix64_next(state)
    return half + draw % half


def backoff_schedule(base_ms, cap_ms, seed, prediction_id, retries):
    """Mirror of ``server::webhook::backoff_schedule``."""
    return [
        backoff_delay_ms(base_ms, cap_ms, a, seed, prediction_id)
        for a in range(1, retries + 1)
    ]


def ewma_update(old, seconds):
    """Mirror of ``Runner::observe_batch_seconds``."""
    if old == 0.0:
        return seconds
    return EWMA_ALPHA * seconds + (1.0 - EWMA_ALPHA) * old


def percentile(sorted_xs, p):
    """Mirror of ``util::stats::percentile`` (linear interpolation)."""
    assert sorted_xs, "percentile of an empty sample"
    if len(sorted_xs) == 1:
        return sorted_xs[0]
    rank = p / 100.0 * (len(sorted_xs) - 1)
    lo = int(math.floor(rank))
    hi = int(math.ceil(rank))
    frac = rank - lo
    return sorted_xs[lo] * (1.0 - frac) + sorted_xs[hi] * frac


def check_unit_vectors():
    """The exact vectors of the Rust unit tests in runner.rs/stats.rs."""
    # estimate_queue_seconds: cold EWMA admits freely.
    assert estimate_queue_seconds(0, 0, 2, 4, 0.0) == 0.0, "cold EWMA -> 0"
    # 12 ahead over 8 slots -> 2 rounds at 0.5 s.
    assert estimate_queue_seconds(7, 4, 2, 4, 0.5) == 1.0, "12 ahead / 8 slots"
    # Single-slot server: 2 ahead -> 2 rounds at 2 s.
    assert estimate_queue_seconds(0, 1, 1, 1, 2.0) == 4.0, "2 ahead / 1 slot"

    assert admission_decision(1.0, 2.0) is None, "under SLO admits"
    assert admission_decision(2.0, 2.0) is None, "at SLO admits"
    assert admission_decision(2.5, 2.0) == 1, "just over SLO -> retry in 1 s"
    assert admission_decision(9.5, 2.0) == 8, "retry-after = ceil(est - slo)"
    assert admission_decision(5.0, 0.0) is None, "slo <= 0 disables shedding"

    assert ewma_update(0.0, 0.4) == 0.4, "first observation seeds the EWMA"
    got = ewma_update(0.4, 0.8)
    assert abs(got - 0.52) < 1e-12, f"0.3*0.8 + 0.7*0.4 = 0.52, got {got}"

    assert percentile([7.0], 99.0) == 7.0, "single sample"
    assert percentile([0.0, 10.0], 50.0) == 5.0, "median interpolates"
    got = percentile([1.0, 2.0, 3.0, 4.0, 5.0], 99.0)
    assert abs(got - 4.96) < 1e-12, f"p99 of 1..5 = 4.96, got {got}"
    print("unit vectors: estimate/admission/ewma/percentile all match runner.rs")


def check_cold_start_vectors():
    """The exact vectors of ``cold_start_admission_uses_the_prior``."""
    # Warm EWMA always wins; idle-and-cold stays 0 (admit the first
    # arrival); busy-and-cold prices batches at the prior.
    assert effective_batch_seconds(0.0, 0.5, 0, 0) == 0.0, "idle cold -> 0"
    assert effective_batch_seconds(0.0, 0.5, 3, 1) == 0.5, "busy cold -> prior"
    assert effective_batch_seconds(0.0, 0.5, 0, 1) == 0.5, "inflight counts as busy"
    assert effective_batch_seconds(0.7, 0.5, 3, 1) == 0.7, "warm EWMA wins"
    assert effective_batch_seconds(0.7, 0.5, 0, 0) == 0.7, "warm EWMA wins when idle too"
    # End to end: a cold burst (10 waiting, 2 inflight, 1 worker x
    # batch 2, prior 0.5 s) estimates 7 rounds x 0.5 = 3.5 s and sheds
    # against a 2 s SLO with Retry-After 2 — where the pre-fix zero
    # estimate admitted unboundedly.
    eff = effective_batch_seconds(0.0, 0.5, 10, 2)
    est = estimate_queue_seconds(10, 2, 1, 2, eff)
    assert est == 3.5, f"cold burst estimate, got {est}"
    assert admission_decision(est, 2.0) == 2, "cold burst sheds with Retry-After 2"
    # The raw estimator itself is unchanged: zero EWMA still prices 0.
    assert estimate_queue_seconds(10, 2, 1, 2, 0.0) == 0.0
    print("cold-start vectors: effective_batch_seconds matches runner.rs")


def check_backoff_vectors():
    """The exact vectors of ``backoff_schedule_is_pinned`` (webhook.rs)."""
    base, cap, seed = 50, 2000, 0xC0FFEE  # WebhookConfig::default()
    assert backoff_schedule(base, cap, seed, 1, 4) == [45, 62, 134, 288]
    assert backoff_schedule(base, cap, seed, 2, 4) == [34, 97, 112, 276]
    assert backoff_schedule(base, cap, seed, 3, 4) == [26, 54, 178, 287]
    # The load generator's fast smoke configuration.
    assert backoff_schedule(10, 50, 7, 1, 4) == [6, 14, 21, 44]
    assert backoff_schedule(10, 50, 7, 2, 4) == [6, 13, 27, 26]
    # Window property: every delay sits in [half, 2*half).
    for pid in range(50):
        for attempt in range(1, 9):
            term = min(base * (1 << min(attempt - 1, 16)), cap)
            half = max(term // 2, 1)
            d = backoff_delay_ms(base, cap, attempt, seed, pid)
            assert half <= d < 2 * half, (pid, attempt, d)
    print("backoff vectors: SplitMix64 jitter schedule matches webhook.rs")


def simulate(n_arrivals, inter_seconds, service_seconds, slo_seconds):
    """Deterministic single-worker, batch-1 queueing simulation.

    Arrivals every ``inter_seconds``; each admitted request takes exactly
    ``service_seconds``; admission uses the mirrored arithmetic with the
    EWMA observed from completed batches (cold until the first
    completion, exactly like the Rust runner). Returns (sorted admitted
    end-to-end latencies, rejected count).
    """
    admitted = []  # (arrival, start, end)
    rejected = 0
    for i in range(n_arrivals):
        t = i * inter_seconds
        # EWMA as the runner would have it: seeded at the first batch
        # completion; with a fixed service time it stays converged.
        ewma = service_seconds if any(end <= t for (_, _, end) in admitted) else 0.0
        waiting = sum(1 for (arr, start, _) in admitted if arr <= t < start)
        inflight = sum(1 for (_, start, end) in admitted if start <= t < end)
        est = estimate_queue_seconds(waiting, inflight, 1, 1, ewma)
        if admission_decision(est, slo_seconds) is not None:
            rejected += 1
            continue
        prev_end = admitted[-1][2] if admitted else 0.0
        start = max(t, prev_end)
        admitted.append((t, start, start + service_seconds))
    latencies = sorted(end - arr for (arr, _, end) in admitted)
    return latencies, rejected


def check_simulation():
    n, inter, service, slo = 50, 0.1, 0.5, 3.0
    controlled, shed = simulate(n, inter, service, slo)
    uncontrolled, shed_off = simulate(n, inter, service, 0.0)

    rows = [
        ("slo=3.0", len(controlled), shed, controlled),
        ("slo off", len(uncontrolled), shed_off, uncontrolled),
    ]
    print(f"\nqueueing simulation: {n} arrivals every {inter} s, "
          f"service {service} s, 1 worker x batch 1")
    print(f"{'policy':>8} {'admitted':>9} {'shed':>5} "
          f"{'p50 s':>7} {'p99 s':>7} {'max s':>7}")
    for name, adm, rej, lats in rows:
        print(f"{name:>8} {adm:>9} {rej:>5} "
              f"{percentile(lats, 50.0):>7.3f} {percentile(lats, 99.0):>7.3f} "
              f"{max(lats):>7.3f}")

    assert shed > 0, "5x overload must shed with the SLO policy on"
    assert shed_off == 0, "slo <= 0 admits everything"
    worst = max(controlled)
    assert worst <= slo + 1e-9, (
        f"admitted tail bounded by the SLO: max {worst} > {slo}"
    )
    assert max(uncontrolled) > slo, "uncontrolled queue blows past the SLO"
    assert percentile(controlled, 99.0) < percentile(uncontrolled, 99.0), (
        "shedding improves the admitted p99"
    )
    print("simulation: shedding bounds the admitted tail at the SLO; "
          "the uncontrolled queue does not")


def main():
    check_unit_vectors()
    check_cold_start_vectors()
    check_backoff_vectors()
    check_simulation()


if __name__ == "__main__":
    main()
