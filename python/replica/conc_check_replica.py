"""Python replica of the deterministic interleaving explorer
(``rust/src/check/sched.rs``) and its protocol models
(``rust/src/check/models.rs``) — no Rust toolchain needed.

The Rust explorer is deliberately deterministic: threads are tried in
ascending id order, the sleep-set is a sorted set, and there is no
randomness anywhere, so the number of explored terminal schedules of a
model under a given preemption bound is an exact, reproducible
constant. ``rust/tests/conc_check.rs`` pins those constants; this
replica re-implements the *same search* (DFS over enabled-thread
choices, sleep-set DPOR cut, preemption bound) and the *same models*
independently in Python, and asserts the identical constants. A drift
in either implementation — a changed model step, a different sleep-set
wake rule, an off-by-one in the preemption accounting — fails one
side's CI.

Mirrored semantics (keep in lockstep with sched.rs):

* terminal = no enabled thread; all-finished -> final_check + result
  string, otherwise a deadlock (counted, with its schedule).
* ``safety()`` runs after every step; a violation terminates that
  branch and counts as one schedule.
* sleep sets: after exploring thread ``t``, ``t`` sleeps for the
  sibling branches; a sleeping thread survives into a child only while
  it stays enabled and its next action is independent (no same-object
  access with a write) of the step just taken.
* preemption: switching away from a still-enabled ``last`` thread
  costs 1; schedules exceeding the bound are pruned and counted.

Run:  python3 python/replica/conc_check_replica.py
"""

# --------------------------------------------------------------------------
# Explorer (mirror of check/sched.rs)
# --------------------------------------------------------------------------

READ, WRITE = False, True


def conflicts(a, b):
    """Accesses are (obj, write) pairs; conflict = same obj, >=1 write."""
    return any(x[0] == y[0] and (x[1] or y[1]) for x in a for y in b)


class Config:
    def __init__(self, preemption_bound=None, max_schedules=5_000_000, max_depth=256):
        self.preemption_bound = preemption_bound
        self.max_schedules = max_schedules
        self.max_depth = max_depth


class Report:
    def __init__(self):
        self.schedules = 0
        self.deadlocks = 0
        self.violations = []
        self.results = set()
        self.preempt_pruned = 0
        self.sleep_pruned = 0
        self.truncated = False

    def is_clean(self):
        return not self.violations and self.deadlocks == 0 and len(self.results) <= 1


def explore(model, cfg):
    report = Report()
    _dfs(model, None, 0, frozenset(), cfg, report, [])
    return report


def _dfs(state, last, preemptions, sleep, cfg, report, trace):
    if report.truncated:
        return
    n = state.threads()
    enabled = [t for t in range(n) if state.enabled(t)]
    if not enabled:
        if report.schedules >= cfg.max_schedules:
            report.truncated = True
            return
        report.schedules += 1
        if all(state.finished(t) for t in range(n)):
            err = state.final_check()
            if err is None:
                report.results.add(state.result())
            else:
                report.violations.append(
                    "final-check failed after [%s]: %s" % (_ts(trace), err)
                )
        else:
            stuck = " ".join("T%d" % t for t in range(n) if not state.finished(t))
            report.deadlocks += 1
            report.violations.append(
                "deadlock after [%s]: %s blocked with no enabled thread"
                % (_ts(trace), stuck)
            )
        return
    if len(trace) >= cfg.max_depth:
        report.truncated = True
        return
    local_sleep = set(sleep)
    for t in enabled:
        if t in local_sleep:
            report.sleep_pruned += 1
            continue
        if last is not None and last != t and state.enabled(last):
            p = preemptions + 1
        else:
            p = preemptions
        if cfg.preemption_bound is not None and p > cfg.preemption_bound:
            report.preempt_pruned += 1
            continue
        nxt = state.clone()
        acc = nxt.step(t)
        trace.append(t)
        err = nxt.safety()
        if err is not None:
            if report.schedules >= cfg.max_schedules:
                report.truncated = True
            else:
                report.schedules += 1
                report.violations.append(
                    "safety violated after [%s]: %s" % (_ts(trace), err)
                )
        else:
            child_sleep = set()
            for s in sorted(local_sleep):
                if s == t or not nxt.enabled(s):
                    continue
                probe = nxt.clone()
                acc_s = probe.step(s)
                if not conflicts(acc, acc_s):
                    child_sleep.add(s)
            _dfs(nxt, t, p, child_sleep, cfg, report, trace)
        trace.pop()
        local_sleep.add(t)


def _ts(trace):
    return ",".join(str(t) for t in trace)


# --------------------------------------------------------------------------
# Models (mirrors of check/models.rs; safety/final_check return an error
# string or None)
# --------------------------------------------------------------------------

LIVE, CANCELLED, EXPIRED = 0, 1, 2


class CancelModel:
    """T0 cancel-CAS, T1 expire-CAS, T2 observer reading twice."""

    def __init__(self):
        self.state = LIVE
        self.wins = [False, False]
        self.writer_done = [False, False]
        self.obs_pc = 0
        self.obs_first = LIVE
        self.unstable = False

    def clone(self):
        c = CancelModel.__new__(CancelModel)
        c.state = self.state
        c.wins = list(self.wins)
        c.writer_done = list(self.writer_done)
        c.obs_pc = self.obs_pc
        c.obs_first = self.obs_first
        c.unstable = self.unstable
        return c

    def threads(self):
        return 3

    def finished(self, tid):
        if tid in (0, 1):
            return self.writer_done[tid]
        return self.obs_pc == 2

    def enabled(self, tid):
        return not self.finished(tid)

    def step(self, tid):
        if tid in (0, 1):
            cause = CANCELLED if tid == 0 else EXPIRED
            if self.state == LIVE:
                self.state = cause
                self.wins[tid] = True
            self.writer_done[tid] = True
            return [(0, WRITE)]
        if self.obs_pc == 0:
            self.obs_first = self.state
            self.obs_pc = 1
        else:
            if self.obs_first != LIVE and self.state != self.obs_first:
                self.unstable = True
            self.obs_pc = 2
        return [(0, READ)]

    def safety(self):
        if self.wins[0] and self.wins[1]:
            return "both cancel and expire won the CAS"
        if self.unstable:
            return "terminal cause changed after being observed"
        return None

    def final_check(self):
        wins = int(self.wins[0]) + int(self.wins[1])
        if wins != 1:
            return "%d terminal causes recorded, want exactly 1" % wins
        if self.state == LIVE:
            return "cell still LIVE after both writers ran"
        return None

    def result(self):
        return "winners=%d" % (int(self.wins[0]) + int(self.wins[1]))


class SlotModel:
    """P0 fills slot 0, P1 fills slot 1, C syncs slot 1 then slot 0."""

    def __init__(self, mutant_drop_notify):
        self.filled = [False, False]
        self.val = [0, 0]
        self.got = [0, 0]
        self.producer_done = [False, False]
        self.consumer_pc = 0
        self.consumer_waiting_on = None
        self.mutant_drop_notify = mutant_drop_notify

    def clone(self):
        c = SlotModel.__new__(SlotModel)
        c.filled = list(self.filled)
        c.val = list(self.val)
        c.got = list(self.got)
        c.producer_done = list(self.producer_done)
        c.consumer_pc = self.consumer_pc
        c.consumer_waiting_on = self.consumer_waiting_on
        c.mutant_drop_notify = self.mutant_drop_notify
        return c

    def threads(self):
        return 3

    def finished(self, tid):
        if tid in (0, 1):
            return self.producer_done[tid]
        return self.consumer_pc == 2

    def enabled(self, tid):
        if tid in (0, 1):
            return not self.producer_done[tid]
        return self.consumer_pc != 2 and self.consumer_waiting_on is None

    def step(self, tid):
        if tid in (0, 1):
            self.val[tid] = 10 * (tid + 1)
            self.filled[tid] = True
            self.producer_done[tid] = True
            if not self.mutant_drop_notify and self.consumer_waiting_on == tid:
                self.consumer_waiting_on = None  # broadcast wake
            return [(tid, WRITE)]
        s = 1 if self.consumer_pc == 0 else 0
        if self.filled[s]:
            self.got[s] = self.val[s]
            self.consumer_pc += 1
        else:
            self.consumer_waiting_on = s
        return [(s, WRITE)]

    def safety(self):
        return None

    def final_check(self):
        if self.got != [10, 20]:
            return "stitched values %r, want [10, 20]" % (self.got,)
        return None

    def result(self):
        return "got1=%d got0=%d" % (self.got[1], self.got[0])


class TwoLockModel:
    """Two threads, two locks; the mutant inverts thread 1's order."""

    def __init__(self, mutant_inverted):
        self.owner = [None, None]
        self.pc = [0, 0]
        self.mutant_inverted = mutant_inverted

    def clone(self):
        c = TwoLockModel.__new__(TwoLockModel)
        c.owner = list(self.owner)
        c.pc = list(self.pc)
        c.mutant_inverted = self.mutant_inverted
        return c

    def order(self, tid):
        if tid == 1 and self.mutant_inverted:
            return [1, 0]
        return [0, 1]

    def threads(self):
        return 2

    def finished(self, tid):
        return self.pc[tid] == 4

    def enabled(self, tid):
        pc = self.pc[tid]
        if pc >= 4:
            return False
        ord_ = self.order(tid)
        if pc == 0:
            return self.owner[ord_[0]] is None
        if pc == 1:
            return self.owner[ord_[1]] is None
        return True

    def step(self, tid):
        ord_ = self.order(tid)
        pc = self.pc[tid]
        if pc == 0:
            self.owner[ord_[0]] = tid
            lock = ord_[0]
        elif pc == 1:
            self.owner[ord_[1]] = tid
            lock = ord_[1]
        elif pc == 2:
            self.owner[ord_[1]] = None
            lock = ord_[1]
        else:
            self.owner[ord_[0]] = None
            lock = ord_[0]
        self.pc[tid] = pc + 1
        return [(lock, WRITE)]

    def safety(self):
        return None

    def final_check(self):
        if self.owner != [None, None]:
            return "locks still held at exit: %r" % (self.owner,)
        return None

    def result(self):
        return ""


class RendezvousModel:
    """Members M0/M1 rendezvous; T2 leaves. Quorum 3 shrinks to 2."""

    def __init__(self, mutant_drop_notify, mutant_no_requeue_check):
        self.arrived = 0
        self.active = 3
        self.generation = 0
        self.staged_sum = 0
        self.output = None
        self.member_pc = [0, 0]
        self.member_out = [0, 0]
        self.leaver_done = False
        self.mutant_drop_notify = mutant_drop_notify
        self.mutant_no_requeue_check = mutant_no_requeue_check

    def clone(self):
        c = RendezvousModel.__new__(RendezvousModel)
        c.arrived = self.arrived
        c.active = self.active
        c.generation = self.generation
        c.staged_sum = self.staged_sum
        c.output = self.output
        c.member_pc = list(self.member_pc)
        c.member_out = list(self.member_out)
        c.leaver_done = self.leaver_done
        c.mutant_drop_notify = self.mutant_drop_notify
        c.mutant_no_requeue_check = self.mutant_no_requeue_check
        return c

    def _complete(self):
        self.output = self.staged_sum
        self.generation += 1
        self._broadcast()

    def _broadcast(self):
        for i in range(2):
            if self.member_pc[i] == 1:
                self.member_pc[i] = 2

    def threads(self):
        return 3

    def finished(self, tid):
        if tid in (0, 1):
            return self.member_pc[tid] == 3
        return self.leaver_done

    def enabled(self, tid):
        if tid in (0, 1):
            return self.member_pc[tid] in (0, 2)
        return not self.leaver_done

    def step(self, tid):
        if tid == 2:
            self.active -= 1
            if not self.mutant_drop_notify:
                self._broadcast()
            self.leaver_done = True
            return [(0, WRITE)]
        pc = self.member_pc[tid]
        if pc == 0:
            self.staged_sum += tid + 1
            self.arrived += 1
            if self.arrived == self.active:
                self._complete()
                self.member_out[tid] = self.output
                self.member_pc[tid] = 3
            else:
                self.member_pc[tid] = 1
        elif pc == 2:
            if self.generation > 0:
                self.member_out[tid] = self.output
                self.member_pc[tid] = 3
            elif not self.mutant_no_requeue_check and self.arrived == self.active:
                self._complete()
                self.member_out[tid] = self.output
                self.member_pc[tid] = 3
            else:
                self.member_pc[tid] = 1
        else:
            raise AssertionError("member %d stepped at pc %d" % (tid, pc))
        return [(0, WRITE)]

    def safety(self):
        if self.active > 3:
            return "quorum grew: active %d" % self.active
        if self.arrived > 3:
            return "arrived %d overran the membership" % self.arrived
        if self.generation > 1:
            return "batch completed twice"
        return None

    def final_check(self):
        if self.generation != 1:
            return "generation %d != 1 at exit" % self.generation
        if self.member_out != [3, 3]:
            return "member outputs %r, want [3, 3]" % (self.member_out,)
        if self.arrived != self.active:
            return "arrived %d != active %d at exit" % (self.arrived, self.active)
        return None

    def result(self):
        out = self.output if self.output is not None else -1
        return "gen=%d out=%d,%d merged=%d" % (
            self.generation,
            self.member_out[0],
            self.member_out[1],
            out,
        )


class DrainModel:
    """Producer (2 pushes) races close(); worker drains then stops."""

    def __init__(self, mutant_drop_notify):
        self.queue = []
        self.closed = False
        self.producer_pc = 0
        self.accepted = 0
        self.refused = 0
        self.drainer_done = False
        self.popped = []
        self.worker_done = False
        self.worker_waiting = False
        self.mutant_drop_notify = mutant_drop_notify

    def clone(self):
        c = DrainModel.__new__(DrainModel)
        c.queue = list(self.queue)
        c.closed = self.closed
        c.producer_pc = self.producer_pc
        c.accepted = self.accepted
        c.refused = self.refused
        c.drainer_done = self.drainer_done
        c.popped = list(self.popped)
        c.worker_done = self.worker_done
        c.worker_waiting = self.worker_waiting
        c.mutant_drop_notify = self.mutant_drop_notify
        return c

    def threads(self):
        return 3

    def finished(self, tid):
        if tid == 0:
            return self.producer_pc == 2
        if tid == 1:
            return self.drainer_done
        return self.worker_done

    def enabled(self, tid):
        if tid == 0:
            return self.producer_pc != 2
        if tid == 1:
            return not self.drainer_done
        return not self.worker_done and not self.worker_waiting

    def step(self, tid):
        if tid == 0:
            v = self.producer_pc + 1
            if self.closed:
                self.refused += 1
            else:
                self.queue.append(v)
                self.accepted += 1
                self.worker_waiting = False  # push broadcasts
            self.producer_pc += 1
            return [(0, WRITE)]
        if tid == 1:
            self.closed = True
            if not self.mutant_drop_notify:
                self.worker_waiting = False  # close broadcasts
            self.drainer_done = True
            return [(0, WRITE)]
        if self.queue:
            self.popped.append(self.queue.pop(0))
        elif self.closed:
            self.worker_done = True
        else:
            self.worker_waiting = True
        return [(0, WRITE)]

    def safety(self):
        if self.accepted + self.refused > 2:
            return "producer pushed more than twice"
        return None

    def final_check(self):
        if len(self.popped) != self.accepted:
            return "accepted %d requests but drained %d — drain lost work" % (
                self.accepted,
                len(self.popped),
            )
        if self.queue:
            return "%d requests stranded in the queue" % len(self.queue)
        if self.accepted + self.refused != 2:
            return "push accounting does not cover both attempts"
        return None

    def result(self):
        return ""


OBJ_CTR, OBJ_MTX, OBJ_CV = 0, 1, 2


class PoolIdleModel:
    """Fine-grained wait_idle model; the mutant notifies unlocked."""

    def __init__(self, mutant_unlocked_notify):
        self.in_flight = 1
        self.mutex_owner = None
        self.waiter_parked = False
        self.worker_pc = 0
        self.waiter_pc = 0
        self.last_read = -1
        self.mutant_unlocked_notify = mutant_unlocked_notify

    def clone(self):
        c = PoolIdleModel.__new__(PoolIdleModel)
        c.in_flight = self.in_flight
        c.mutex_owner = self.mutex_owner
        c.waiter_parked = self.waiter_parked
        c.worker_pc = self.worker_pc
        c.waiter_pc = self.waiter_pc
        c.last_read = self.last_read
        c.mutant_unlocked_notify = self.mutant_unlocked_notify
        return c

    def _worker_done_pc(self):
        return 2 if self.mutant_unlocked_notify else 4

    def threads(self):
        return 2

    def finished(self, tid):
        if tid == 0:
            return self.worker_pc == self._worker_done_pc()
        return self.waiter_pc == 5

    def enabled(self, tid):
        if tid == 0:
            if self.worker_pc == self._worker_done_pc():
                return False
            if not self.mutant_unlocked_notify and self.worker_pc == 1:
                return self.mutex_owner is None
            return True
        pc = self.waiter_pc
        if pc in (0, 4):
            return self.mutex_owner is None
        if pc == 3:
            return not self.waiter_parked
        if pc == 5:
            return False
        return True

    def step(self, tid):
        if tid == 0:
            if self.mutant_unlocked_notify:
                if self.worker_pc == 0:
                    self.in_flight -= 1
                    self.worker_pc = 1
                    return [(OBJ_CTR, WRITE)]
                if self.waiter_parked:
                    self.waiter_parked = False
                    self.waiter_pc = 4
                self.worker_pc = 2
                return [(OBJ_CV, WRITE)]
            if self.worker_pc == 0:
                self.in_flight -= 1
                self.worker_pc = 1
                return [(OBJ_CTR, WRITE)]
            if self.worker_pc == 1:
                self.mutex_owner = 0
                self.worker_pc = 2
                return [(OBJ_MTX, WRITE)]
            if self.worker_pc == 2:
                if self.waiter_parked:
                    self.waiter_parked = False
                    self.waiter_pc = 4
                self.worker_pc = 3
                return [(OBJ_CV, WRITE)]
            self.mutex_owner = None
            self.worker_pc = 4
            return [(OBJ_MTX, WRITE)]
        pc = self.waiter_pc
        if pc in (0, 4):
            self.mutex_owner = 1
            self.waiter_pc = 1
            return [(OBJ_MTX, WRITE)]
        if pc == 1:
            self.last_read = self.in_flight
            self.waiter_pc = 2 if self.last_read == 0 else 3
            return [(OBJ_CTR, READ)]
        if pc == 2:
            self.mutex_owner = None
            self.waiter_pc = 5
            return [(OBJ_MTX, WRITE)]
        # park: atomically release the mutex + join waitset
        self.mutex_owner = None
        self.waiter_parked = True
        return [(OBJ_MTX, WRITE), (OBJ_CV, WRITE)]

    def safety(self):
        if self.in_flight < 0:
            return "in_flight underflowed: %d" % self.in_flight
        return None

    def final_check(self):
        if self.in_flight != 0:
            return "in_flight %d != 0 at exit" % self.in_flight
        if self.mutex_owner is not None:
            return "done mutex still held at exit"
        if self.last_read != 0:
            return "waiter returned without observing idle"
        return None

    def result(self):
        return "idle_observed=%d" % (1 if self.last_read == 0 else 0)


# --------------------------------------------------------------------------
# The sweep: the same (model, bound) grid tests/conc_check.rs pins.
# --------------------------------------------------------------------------

# Exact explored-schedule counts per (model, preemption bound); None is
# the unbounded exhaustive search. These constants are pinned verbatim
# in rust/tests/conc_check.rs — a drift in either implementation fails
# one side.
EXPECTED_SCHEDULES = {
    ("cancel", 0): 6,
    ("cancel", 1): 12,
    ("cancel", 2): 12,
    ("cancel", 3): 12,
    ("cancel", None): 12,
    ("slot", 0): 4,
    ("slot", 1): 4,
    ("slot", 2): 4,
    ("slot", 3): 4,
    ("slot", None): 4,
    ("twolock", 0): 2,
    ("twolock", 1): 2,
    ("twolock", 2): 2,
    ("twolock", 3): 2,
    ("twolock", None): 2,
    ("rendezvous", 0): 10,
    ("rendezvous", 1): 10,
    ("rendezvous", 2): 10,
    ("rendezvous", 3): 10,
    ("rendezvous", None): 10,
    ("drain", 0): 8,
    ("drain", 1): 26,
    ("drain", 2): 38,
    ("drain", 3): 40,
    ("drain", None): 40,
    ("pool_idle", 0): 2,
    ("pool_idle", 1): 3,
    ("pool_idle", 2): 3,
    ("pool_idle", 3): 3,
    ("pool_idle", None): 3,
}

# (schedules, deadlocks) per mutant at preemption bound 2, also pinned
# in rust/tests/conc_check.rs.
EXPECTED_MUTANTS = {
    "slot_drop_notify": (3, 2),
    "twolock_inverted": (3, 1),
    "rendezvous_drop_notify": (6, 2),
    "rendezvous_no_requeue": (10, 4),
    "drain_drop_notify": (34, 9),
    "pool_unlocked_notify": (3, 1),
}


def sweep():
    grid = [
        ("cancel", lambda: CancelModel()),
        ("slot", lambda: SlotModel(False)),
        ("twolock", lambda: TwoLockModel(False)),
        ("rendezvous", lambda: RendezvousModel(False, False)),
        ("drain", lambda: DrainModel(False)),
        ("pool_idle", lambda: PoolIdleModel(False)),
    ]
    rows = []
    for name, mk in grid:
        for bound in (0, 1, 2, 3, None):
            cfg = Config(preemption_bound=bound)
            r = explore(mk(), cfg)
            assert r.is_clean(), "%s bound=%r not clean: %s" % (
                name,
                bound,
                r.violations[:3],
            )
            assert not r.truncated
            want = EXPECTED_SCHEDULES[(name, bound)]
            assert r.schedules == want, "%s bound=%r: %d schedules, pinned %d" % (
                name,
                bound,
                r.schedules,
                want,
            )
            rows.append((name, bound, r.schedules, r.sleep_pruned, r.preempt_pruned))
    return rows


def mutants():
    grid = [
        ("slot_drop_notify", lambda: SlotModel(True)),
        ("twolock_inverted", lambda: TwoLockModel(True)),
        ("rendezvous_drop_notify", lambda: RendezvousModel(True, False)),
        ("rendezvous_no_requeue", lambda: RendezvousModel(False, True)),
        ("drain_drop_notify", lambda: DrainModel(True)),
        ("pool_unlocked_notify", lambda: PoolIdleModel(True)),
    ]
    rows = []
    for name, mk in grid:
        r = explore(mk(), Config(preemption_bound=2))
        assert r.deadlocks > 0 or r.violations, "%s: mutant not convicted" % name
        want = EXPECTED_MUTANTS[name]
        got = (r.schedules, r.deadlocks)
        assert got == want, "%s: %r, pinned %r" % (name, got, want)
        rows.append((name, r.schedules, r.deadlocks, len(r.violations)))
    return rows


def main():
    print("== clean sweeps (model, bound, schedules, sleep_pruned, preempt_pruned) ==")
    for name, bound, scheds, slept, preempted in sweep():
        b = "inf" if bound is None else str(bound)
        print("%-12s bound=%-4s schedules=%-6d sleep_pruned=%-6d preempt_pruned=%d"
              % (name, b, scheds, slept, preempted))
    print("== mutants convicted at bound 2 (name, schedules, deadlocks, violations) ==")
    for name, scheds, dls, viols in mutants():
        print("%-24s schedules=%-6d deadlocks=%-5d violations=%d"
              % (name, scheds, dls, viols))
    print("conc_check_replica: OK")


if __name__ == "__main__":
    main()
