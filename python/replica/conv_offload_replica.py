"""Python replica of the conv-offload experiment (no Rust toolchain needed).

Re-implements, in deterministic integer math, exactly what
``benches/conv_offload.rs`` measures through the Rust simulator via
``replay_unet_steps_policy``:

* the mini U-Net's **full** op list in dispatch order — quantized
  linears *and* the F16 ``ConvIm2col`` GEMMs (WeightIds minted like
  ``WeightFactory::weight_id`` with seed 1; ``k % block != 0`` linears
  fall back to F16 and stay on the host, as do the F32 attention ops),
* the single-lane ``ImaxBackend`` replay: the plan-compiled pin pass
  (``OpPlan::pin_set_for`` — hottest-first greedy, policy-filtered),
  per-op residency (lookup/insert/LRU-with-pins over the LMM cache
  partition) and the ``breakdown_for_plan_with_residency`` phase
  pricing of ``imax/lane.rs``,
* the **LMM-tiled im2col chunking** of ``run_f16_conv_on_lane``: patch
  rows split so each chunk's f32 activations fit half the transient
  partition; every chunk reuses the *same* weight identity, so the
  first chunk pays the cache fill and the rest hit,
* CONF accounting across the mixed kind sequence (Q8_0/Q3_K linears
  interleaved with F16 convs reconfigure the lane on every switch),
* the host-conv comparison path: the quantized-only replay's warm
  cycles plus the step's conv MACs priced at the ARM A72 F16 rate
  (``device::arm_a72().gmacs_f16`` = 3.0 GMAC/s), in lane clocks.

Two substrates frame the honest finding the bench asserts: on the FPGA
prototype DMA (0.193 B/cycle) the offload REGRESSES — the im2col
activation stream is LOAD-bound, the Fig. 11 lesson — while the ASIC
with a production interconnect (6.7 GB/s, LMM big enough to pin the
whole weight set) beats both the cold step and the host-conv path.

Running it prints the tables recorded in ``EXPERIMENTS.md`` §Conv
offload and asserts the same inequalities the bench and
``tests/weight_cache.rs`` assert, so the recorded numbers and the CI
smoke run measure one definition.
"""

import math

from shard_scaling_replica import shard_plan, weight_id

DMA_SETUP = 4_000
CONF_PER_PE = 16
REGV_PER_PE = 4
RANGE_PER_PE = 4
HOST_GMACS_F16 = 3.0  # device::arm_a72().gmacs_f16

KCFG = {
    # kind: (pe_count, elems_per_beat, groups, pipeline_depth)
    "Q8_0": (46, 32, 3, 16),
    "Q3_K": (51, 16, 3, 18),
    "F16": (46, 16, 3, 16),  # KernelConfig::f16 — OP_SML16 chain
}


class Substrate:
    def __init__(self, name, clock_hz, dma_bpc, lmm, cache, offload_wins):
        self.name = name
        self.clock_hz = clock_hz
        self.dma_bpc = dma_bpc
        self.lmm = lmm
        self.cache = cache
        self.offload_wins = offload_wins

    @property
    def budget(self):
        # LaneSim::new — cache clamped to 3/4 of the LMM.
        return min(self.cache, self.lmm // 4 * 3)

    @property
    def transient(self):
        return self.lmm - self.budget


SUBSTRATES = [
    # ImaxConfig::fpga(1): the calibrated prototype.
    Substrate("FPGA 145MHz, prototype DMA", 145.0e6, 0.193,
              512 << 10, 256 << 10, offload_wins=False),
    # benches/conv_offload.rs ASIC row: 840 MHz, 6.7 GB/s DMA
    # (8 B/cycle), 8 MiB LMM with a 4 MiB cache partition.
    Substrate("ASIC 840MHz, 6.7GB/s DMA, 8M LMM", 840.0e6, 8.0,
              8 << 20, 4 << 20, offload_wins=True),
]


def w_row_bytes(kind, k):
    if kind == "Q8_0":
        return k // 32 * 34
    if kind == "Q3_K":
        return k // 256 * 110
    return k * 2  # F16


def a_row_bytes(kind, k):
    if kind == "Q8_0":
        return k // 32 * 34
    if kind == "Q3_K":
        return k // 256 * (4 + 256 + 2 * 16)
    return k * 4  # acts stay f32 on the F16 path


def transfer(sub, bytes_):
    if bytes_ == 0:
        return 0
    return DMA_SETUP + math.ceil(bytes_ / sub.dma_bpc)


def beats_for_dot(kind, k):
    _, elems, groups, _ = KCFG[kind]
    return -(-(-(-k // elems)) // groups)


def tile_plan(capacity, kind, m, n, k):
    # TilePlan::with_capacity
    wrb, arb = w_row_bytes(kind, k), a_row_bytes(kind, k)
    a_tile = min(max(min(capacity // 2 // arb, max(n, 1)), 1), n)
    while True:
        a_bytes = a_tile * arb
        if a_bytes <= capacity:
            rem = capacity - a_bytes
            per_w_row = wrb + a_tile * 4
            if rem >= per_w_row:
                return dict(m=m, n=n, k=k, a_tile=a_tile,
                            w_tile=min(rem // per_w_row, m), wrb=wrb, arb=arb)
        if a_tile == 1:
            raise MemoryError("K too large for LMM")
        a_tile //= 2


def breakdown(sub, kind, plan, reconf, residency):
    # breakdown_for_plan_with_residency; returns (cycles, act_B, w_B)
    pe, _, _, depth = KCFG[kind]
    cyc = CONF_PER_PE * pe if reconf else 0
    w_load = plan["m"] * plan["wrb"] if residency == "Inserted" else 0
    if residency == "Inserted":
        cyc += transfer(sub, plan["m"] * plan["wrb"])
    act_load = 0
    beats = beats_for_dot(kind, plan["k"])
    at0 = 0
    while at0 < plan["n"]:
        at1 = min(at0 + plan["a_tile"], plan["n"])
        cyc += transfer(sub, (at1 - at0) * plan["arb"])
        act_load += (at1 - at0) * plan["arb"]
        wt0 = 0
        while wt0 < plan["m"]:
            wt1 = min(wt0 + plan["w_tile"], plan["m"])
            cyc += (REGV_PER_PE + RANGE_PER_PE) * pe
            if residency == "Streamed":
                cyc += transfer(sub, (wt1 - wt0) * plan["wrb"])
                w_load += (wt1 - wt0) * plan["wrb"]
            dots = (wt1 - wt0) * (at1 - at0)
            cyc += depth + dots * (beats + 2)
            cyc += transfer(sub, dots * 4)
            wt0 = wt1
        at0 = at1
    return cyc, act_load, w_load


class LaneCache:
    """imax/lmm.rs residency cache: LRU with pins, plus hit-byte stats."""

    def __init__(self, budget):
        self.budget = budget
        self.entries = {}  # wid -> [bytes, tick, pinned]
        self.pin_wish = set()
        self.tick = 0
        self.hits = 0
        self.hit_bytes = 0

    def pinned_bytes(self):
        return sum(b for b, _, p in self.entries.values() if p)

    def used(self):
        return sum(b for b, _, _ in self.entries.values())

    def lookup(self, wid, bytes_):
        self.tick += 1
        if wid in self.entries:
            self.entries[wid][1] = self.tick
            self.hits += 1
            self.hit_bytes += bytes_
            return True
        return False

    def insert(self, wid, bytes_):
        if wid in self.entries:
            return True
        if self.budget == 0 or bytes_ > self.budget - self.pinned_bytes():
            return False
        while self.budget - self.used() < bytes_:
            victims = [(t, w) for w, (b, t, p) in self.entries.items() if not p]
            if not victims:
                return False
            del self.entries[min(victims)[1]]
        self.tick += 1
        self.entries[wid] = [bytes_, self.tick, wid in self.pin_wish]
        return True


def unet_sites(model):
    """All weight-bearing op sites of one step, in dispatch order.

    kind: "lin" (quantized linear, lane), "conv" (F16 ConvIm2col),
    "host" (F16-fallback linear — stays on the host in every policy).
    The F32 attention ops never carry a weight and are omitted.
    """
    C0, C1, TD = 64, 128, 256
    sites = []

    def lin(name, dout, din, n):
        block = 32 if model == "Q8_0" else 256
        if din % block != 0:
            sites.append(dict(name=name, m=dout, k=din, n=n, dtype="F16",
                              kind="host", wid=weight_id(1, name, "F16")))
        else:
            sites.append(dict(name=name, m=dout, k=din, n=n, dtype=model,
                              kind="lin", wid=weight_id(1, name, model)))

    def conv(name, cout, cin, ksz, n):
        sites.append(dict(name=name, m=cout, k=cin * ksz * ksz, n=n,
                          dtype="F16", kind="conv",
                          wid=weight_id(1, name, "F16")))

    def resblock(name, cin, cout, n):
        conv(f"{name}.c1", cout, cin, 3, n)
        lin(f"{name}.emb", cout, 256, 1)
        conv(f"{name}.c2", cout, cout, 3, n)
        if cin != cout:
            conv(f"{name}.skip", cout, cin, 1, n)

    lin("unet.temb1", 256, 64, 1)
    lin("unet.temb2", 256, 256, 1)
    conv("unet.conv_in", C0, 4, 3, 256)
    resblock("unet.down0", C0, C0, 256)
    conv("unet.down", C1, C0, 3, 64)
    resblock("unet.down1", C1, C1, 64)
    tf = "unet.mid.tf"
    lin(f"{tf}.proj_in", TD, C1, 64)
    for a in ["attn1.q", "attn1.k", "attn1.v", "attn1.o", "attn2.q"]:
        lin(f"{tf}.{a}", TD, TD, 64)
    lin(f"{tf}.attn2.k", TD, 256, 77)
    lin(f"{tf}.attn2.v", TD, 256, 77)
    lin(f"{tf}.attn2.o", TD, TD, 64)
    lin(f"{tf}.ff1", 2 * TD, TD, 64)
    lin(f"{tf}.ff2", TD, TD, 64)
    lin(f"{tf}.proj_out", C1, TD, 64)
    resblock("unet.mid.rb", C1, C1, 64)
    resblock("unet.up0", C1 + C1, C1, 64)
    resblock("unet.up1", C1 + C0, C0, 256)
    conv("unet.conv_out", 4, C0, 3, 256)
    return sites


def conv_macs(model):
    return sum(s["m"] * s["k"] * s["n"]
               for s in unet_sites(model) if s["kind"] == "conv")


def lane_eligible(site, policy):
    if site["kind"] == "lin":
        return True
    return site["kind"] == "conv" and policy == "QuantizedAndConv"


def replay(model, sub, policy, steps):
    """replay_unet_steps_policy on one simulated lane."""
    sites = unet_sites(model)
    cache = LaneCache(sub.budget)
    configured = [None]  # lane kernel kind, persists across steps

    # OpPlan::pin_set_for — hottest-first greedy over the eligible
    # weights (streamed bytes desc, wid asc), policy-filtered.
    uses = []
    for s in sites:
        if s["kind"] == "host":
            continue  # not offload-eligible, never aggregated
        if not lane_eligible(s, policy):
            continue
        uses.append((s["wid"], s["m"] * w_row_bytes(
            "F16" if s["kind"] == "conv" else model, s["k"])))
    remaining = sub.budget
    for wid, bytes_ in sorted(uses, key=lambda u: (-u[1], u[0])):
        if bytes_ <= remaining:
            remaining -= bytes_
            cache.pin_wish.add(wid)

    results = []
    for _ in range(steps):
        cyc = load = 0
        h0, hb0 = cache.hits, cache.hit_bytes
        for s in sites:
            if not lane_eligible(s, policy):
                continue  # host op: no lane cost
            kind = "F16" if s["kind"] == "conv" else model
            wb = s["m"] * w_row_bytes(kind, s["k"])
            if s["kind"] == "conv":
                # run_f16_conv_on_lane: LMM-tiled im2col chunks, all
                # under the same weight identity.
                rows_per = min(max(sub.transient // 2
                                   // a_row_bytes("F16", s["k"]), 1), s["n"])
                r0 = 0
                while r0 < s["n"]:
                    rows = min(rows_per, s["n"] - r0)
                    if cache.lookup(s["wid"], wb):
                        residency = "Resident"
                    elif cache.insert(s["wid"], wb):
                        residency = "Inserted"
                    else:
                        residency = "Streamed"
                    plan = tile_plan(sub.transient, kind, s["m"], rows, s["k"])
                    reconf = configured[0] != kind
                    configured[0] = kind
                    dc, da, dw = breakdown(sub, kind, plan, reconf, residency)
                    cyc += dc
                    load += da + dw
                    r0 += rows
            else:
                if cache.lookup(s["wid"], wb):
                    residency = "Resident"
                elif cache.insert(s["wid"], wb):
                    residency = "Inserted"
                else:
                    residency = "Streamed"
                plan = tile_plan(sub.transient, kind, s["m"], s["n"], s["k"])
                reconf = configured[0] != kind
                configured[0] = kind
                dc, da, dw = breakdown(sub, kind, plan, reconf, residency)
                cyc += dc
                load += da + dw
        results.append(dict(cycles=cyc, load_bytes=load,
                            hits=cache.hits - h0,
                            hit_bytes=cache.hit_bytes - hb0))
    return results


def min_shard_rows(sub, kind, k, n):
    # Coordinator::min_shard_rows with the weight's kernel kind.
    pe = KCFG[kind][0]
    fixed = 3 * DMA_SETUP + (REGV_PER_PE + RANGE_PER_PE + CONF_PER_PE) * pe
    stream = lambda b: math.ceil(b / sub.dma_bpc)
    row_cycles = (n * (beats_for_dot(kind, k) + 2)
                  + stream(w_row_bytes(kind, k)) + stream(n * 4))
    return -(-(4 * fixed) // max(row_cycles, 1))


def op_shards(sub, op, kind, lanes):
    # Coordinator::shard_geometry for one dispatch site.
    rb = w_row_bytes(kind, op["k"])
    if sub.budget == 0 or rb == 0 or rb > sub.budget:
        cap = max(op["m"], 1)
    else:
        cap = sub.budget // rb
    return shard_plan(op["m"], lanes, cap,
                      min_shard_rows(sub, kind, op["k"], op["n"]), op["wid"])


def replay_sharded(model, sub, lanes, steps):
    """replay_unet_steps_sharded_policy(QuantizedAndConv) on the FPGA:
    per-op row-tile shards over per-lane caches, activation broadcast
    elision on shards i > 0."""
    sites = [s for s in unet_sites(model) if s["kind"] != "host"]
    caches = [LaneCache(sub.budget) for _ in range(lanes)]
    configured = [None] * lanes

    # apply_plan_sharded: hottest-first, per-lane remaining budgets.
    uses = []
    for s in sites:
        kind = "F16" if s["kind"] == "conv" else model
        uses.append((s, kind, s["m"] * w_row_bytes(kind, s["k"])))
    remaining = [sub.budget] * lanes
    for s, kind, bytes_ in sorted(uses, key=lambda u: (-u[2], u[0]["wid"])):
        rb = bytes_ // s["m"]
        for sh in op_shards(sub, s, kind, lanes):
            b = sh["rows"] * rb
            if b <= remaining[sh["lane"]]:
                remaining[sh["lane"]] -= b
                caches[sh["lane"]].pin_wish.add(sh["wid"])

    results = []
    for _ in range(steps):
        cyc = [0] * lanes
        wload = [0] * lanes
        for s in sites:
            kind = "F16" if s["kind"] == "conv" else model
            rb = w_row_bytes(kind, s["k"])
            for i, sh in enumerate(op_shards(sub, s, kind, lanes)):
                lane, c = sh["lane"], caches[sh["lane"]]
                wb = sh["rows"] * rb
                if c.lookup(sh["wid"], wb):
                    residency = "Resident"
                elif c.insert(sh["wid"], wb):
                    residency = "Inserted"
                else:
                    residency = "Streamed"
                plan = tile_plan(sub.transient, kind, sh["rows"],
                                 s["n"], s["k"])
                reconf = configured[lane] != kind
                configured[lane] = kind
                dc, _da, dw = breakdown(sub, kind, plan, reconf, residency)
                cyc[lane] += dc
                wload[lane] += dw
        results.append(dict(max_cyc=max(cyc), max_wload=max(wload)))
    return results


def main():
    print("conv_offload replica: mini U-Net step, F16 ConvIm2col via "
          "OP_SML16\n")
    for model in ["Q8_0", "Q3_K"]:
        macs = conv_macs(model)
        wbytes = sum(s["m"] * w_row_bytes("F16", s["k"])
                     for s in unet_sites(model) if s["kind"] == "conv")
        abytes = sum(s["n"] * a_row_bytes("F16", s["k"])
                     for s in unet_sites(model) if s["kind"] == "conv")
        print(f"{model}: conv MACs/step {macs} "
              f"({macs / 1e6:.1f} M), F16 conv weights {wbytes} B, "
              f"im2col acts {abytes} B")
        assert macs > 100_000_000, "convs must dominate the step"
    print()

    hdr = (f"{'model':6} {'substrate':32} {'cold Mcyc':>10} "
           f"{'warm Mcyc':>10} {'warm LOAD B':>12} {'warm hits':>9} "
           f"{'host Mcyc':>10} {'warm/host':>9}")
    print(hdr)
    print("-" * len(hdr))
    for model in ["Q8_0", "Q3_K"]:
        for sub in SUBSTRATES:
            run = replay(model, sub, "QuantizedAndConv", 3)
            quant = replay(model, sub, "QuantizedOnly", 3)
            cold, warm = run[0], run[1]
            host_cyc = int(conv_macs(model) / (HOST_GMACS_F16 * 1e9)
                           * sub.clock_hz)
            host_path = quant[1]["cycles"] + host_cyc
            ratio = warm["cycles"] / host_path
            print(f"{model:6} {sub.name:32} "
                  f"{cold['cycles'] / 1e6:>10.2f} "
                  f"{warm['cycles'] / 1e6:>10.2f} "
                  f"{warm['load_bytes']:>12} {warm['hits']:>9} "
                  f"{host_path / 1e6:>10.2f} {ratio:>8.2f}x")
            # The inequalities tests/weight_cache.rs and the bench assert.
            assert run[1] == run[2], "warm steps must be steady-state"
            if sub.offload_wins:
                # Only claimed where the cache pins the whole weight set.
                # On the 256 KiB FPGA budget the pin pass locks the
                # cache, so mid-sized conv weights that cached
                # transiently during the cold step (insert once, hit on
                # later im2col chunks) re-stream every warm chunk —
                # warm can legitimately exceed cold there.
                assert warm["cycles"] < cold["cycles"], "residency pays off"
                assert warm["cycles"] < host_path, \
                    "offload must win on the production interconnect"
            else:
                assert warm["cycles"] > host_path, \
                    "offload must regress on the prototype DMA (Fig. 11)"
    print("\nhost Mcyc = quantized-only warm lane cycles + conv MACs at "
          f"the A72 F16 rate ({HOST_GMACS_F16:.1f} GMAC/s), in lane "
          "clocks.\nThe offload wins only with the production "
          "interconnect; on the prototype DMA the im2col\nactivation "
          "stream is LOAD-bound and the offload regresses (the Fig. 11 "
          "lesson).\n")

    # The FPGA chunk geometry run_f16_conv_on_lane derives: chunks =
    # ceil(n / (transient/2 // 4k)), weight cacheable iff m·2k fits the
    # 256 KiB budget.
    fpga = SUBSTRATES[0]
    print(f"FPGA im2col chunking (transient {fpga.transient} B, "
          f"cache budget {fpga.budget} B):")
    print(f"  {'conv site':18} {'m':>4} {'k':>5} {'n':>4} "
          f"{'chunks':>6} {'w bytes':>8} {'cacheable':>9}")
    for s in unet_sites("Q8_0"):
        if s["kind"] != "conv":
            continue
        rows_per = min(max(fpga.transient // 2
                           // a_row_bytes("F16", s["k"]), 1), s["n"])
        wb = s["m"] * w_row_bytes("F16", s["k"])
        print(f"  {s['name']:18} {s['m']:>4} {s['k']:>5} {s['n']:>4} "
              f"{-(-s['n'] // rows_per):>6} {wb:>8} "
              f"{str(wb <= fpga.budget):>9}")

    # The sharded section of benches/conv_offload.rs: row-tile shards of
    # the conv + quantized weights over 1-8 lanes, 64 KiB cache/lane.
    sharded = Substrate("FPGA sharded", 145.0e6, 0.193,
                        512 << 10, 64 << 10, offload_wins=False)
    print(f"\nsharded conv offload (FPGA, {sharded.lmm >> 10} KiB LMM, "
          f"{sharded.budget >> 10} KiB cache/lane):")
    hdr = (f"{'model':6} {'lanes':>5} {'cold ms':>8} {'warm ms':>8} "
           f"{'cold wLOAD B/lane':>18} {'warm wLOAD B/lane':>18}")
    print(hdr)
    print("-" * len(hdr))
    for model in ["Q8_0", "Q3_K"]:
        prev_w = prev_cyc = None
        for lanes in [1, 2, 4, 8]:
            cold, warm = replay_sharded(model, sharded, lanes, 2)
            ms = lambda c: c / sharded.clock_hz * 1e3
            print(f"{model:6} {lanes:>5} {ms(cold['max_cyc']):>8.2f} "
                  f"{ms(warm['max_cyc']):>8.2f} {cold['max_wload']:>18} "
                  f"{warm['max_wload']:>18}")
            # The bench's conv-on assertion set. Warm-vs-cold is NOT
            # claimed: the 64 KiB/lane budget pins only a slice of the
            # conv weight set, and shards that cached transiently during
            # the cold step re-stream every warm step, so warm exceeds
            # cold per lane. What holds is the monotone warm shrink.
            assert prev_w is None or warm["max_wload"] < prev_w, \
                f"{model}: warm per-lane weight LOAD must shrink at {lanes}"
            assert prev_cyc is None or warm["max_cyc"] < prev_cyc, \
                f"{model}: warm lane wall-clock must improve at {lanes}"
            prev_w, prev_cyc = warm["max_wload"], warm["max_cyc"]
    print("\nper-lane conv weight LOAD shrinks with lanes: row-tile "
          "shards pin per lane and the\nim2col activation stream is "
          "broadcast-elided (tests/shard_props.rs).")


if __name__ == "__main__":
    main()
