"""L2 model tests: shapes, determinism, quantization closeness."""

import numpy as np
import jax.numpy as jnp

from compile import model


def _inputs(seed=5):
    r = np.random.RandomState(seed)
    x = r.randn(model.SEQ, model.DIM).astype(np.float32) * 0.5
    ctx = r.randn(model.CTX_LEN, model.DIM).astype(np.float32) * 0.3
    return jnp.asarray(x), jnp.asarray(ctx)


def test_block_shape_and_determinism():
    block = model.make_transformer_block()
    x, ctx = _inputs()
    (a,) = block(x, ctx)
    (b,) = block(x, ctx)
    assert a.shape == (model.SEQ, model.DIM)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert np.isfinite(np.asarray(a)).all()


def test_block_responds_to_context():
    block = model.make_transformer_block()
    x, ctx = _inputs()
    (a,) = block(x, ctx)
    (b,) = block(x, ctx * -1.0)
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_different_seeds_different_weights():
    x, ctx = _inputs()
    (a,) = model.make_transformer_block(seed=1)(x, ctx)
    (b,) = model.make_transformer_block(seed=2)(x, ctx)
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_standalone_kernel_entries_run():
    fn = model.make_q8_0_matmul(8, 8, 64)
    wq = jnp.zeros((8, 64), jnp.int8)
    wd = jnp.zeros((8, 2), jnp.float32)
    (out,) = fn(wq, wd, wq, wd)
    assert out.shape == (8, 8)
    assert (np.asarray(out) == 0).all()
