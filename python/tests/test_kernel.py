"""Kernel vs ref allclose — the CORE L1 correctness signal.

Hypothesis sweeps shapes and value ranges; every Pallas kernel must match
its pure-jnp oracle to float tolerance (identical arithmetic, different
scheduling) and the plain f32 mat-mul within quantization noise.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.f16_dot import matmul_f16
from compile.kernels.q3_k import matmul_q3_imax
from compile.kernels.q8_0 import matmul_q8_0, vmem_bytes
from compile.kernels.quantize import quantize_q3_imax, quantize_q8_0, quantize_q8_k


def rnd(shape, seed, scale=1.0):
    return (np.random.RandomState(seed).randn(*shape) * scale).astype(np.float32)


@settings(max_examples=12, deadline=None)
@given(
    m=st.sampled_from([8, 16, 32]),
    n=st.sampled_from([8, 16, 32]),
    kb=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([0.1, 1.0, 8.0]),
)
def test_q8_0_kernel_matches_ref(m, n, kb, seed, scale):
    k = 32 * kb
    w = rnd((m, k), seed, scale)
    x = rnd((n, k), seed + 1, scale)
    wq, wd = quantize_q8_0(w)
    xq, xd = quantize_q8_0(x)
    got = matmul_q8_0(jnp.asarray(wq), jnp.asarray(wd), jnp.asarray(xq), jnp.asarray(xd),
                      block_m=min(8, m), block_n=min(8, n))
    want = ref.matmul_q8_0(jnp.asarray(wq), jnp.asarray(wd), jnp.asarray(xq), jnp.asarray(xd))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_q8_0_close_to_f32_matmul():
    w = rnd((16, 256), 7)
    x = rnd((8, 256), 8)
    wq, wd = quantize_q8_0(w)
    xq, xd = quantize_q8_0(x)
    got = np.asarray(matmul_q8_0(jnp.asarray(wq), jnp.asarray(wd), jnp.asarray(xq), jnp.asarray(xd)))
    want = x @ w.T
    tol = 0.02 * np.abs(want).max() + 0.05
    assert np.abs(got - want).max() < tol, "quantization noise bound"


@settings(max_examples=8, deadline=None)
@given(
    m=st.sampled_from([8, 16]),
    n=st.sampled_from([8, 16]),
    kb=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_q3_imax_kernel_matches_ref(m, n, kb, seed):
    k = 256 * kb
    w = rnd((m, k), seed)
    x = rnd((n, k), seed + 1)
    q3, s5, d = quantize_q3_imax(w)
    xq, xd = quantize_q8_k(x)
    got = matmul_q3_imax(
        jnp.asarray(q3.astype(np.int8)), jnp.asarray(s5), jnp.asarray(d),
        jnp.asarray(xq), jnp.asarray(xd), block_m=min(8, m), block_n=min(8, n))
    want = ref.matmul_q3_imax(
        jnp.asarray(q3.astype(np.int8)), jnp.asarray(s5), jnp.asarray(d),
        jnp.asarray(xq), jnp.asarray(xd))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_q3_imax_tracks_f32_matmul():
    w = rnd((8, 512), 3)
    x = rnd((4, 512), 4)
    q3, s5, d = quantize_q3_imax(w)
    xq, xd = quantize_q8_k(x)
    got = np.asarray(matmul_q3_imax(
        jnp.asarray(q3.astype(np.int8)), jnp.asarray(s5), jnp.asarray(d),
        jnp.asarray(xq), jnp.asarray(xd)))
    want = x @ w.T
    # 3-bit weights + 5-bit scales: coarse.
    denom = np.abs(want).max()
    assert np.abs(got - want).max() / denom < 0.35


@settings(max_examples=8, deadline=None)
@given(
    m=st.sampled_from([16, 64]),
    n=st.sampled_from([16, 64]),
    k=st.sampled_from([32, 96, 256]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_f16_kernel_matches_ref(m, n, k, seed):
    w = rnd((m, k), seed)
    x = rnd((n, k), seed + 1)
    got = matmul_f16(jnp.asarray(w), jnp.asarray(x), block_m=16, block_n=16)
    want = ref.matmul_f16(jnp.asarray(w).astype(jnp.float16), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_block_shape_invariance():
    # Different BlockSpec tilings must not change the numbers.
    w = rnd((32, 256), 11)
    x = rnd((32, 256), 12)
    wq, wd = quantize_q8_0(w)
    xq, xd = quantize_q8_0(x)
    args = (jnp.asarray(wq), jnp.asarray(wd), jnp.asarray(xq), jnp.asarray(xd))
    a = np.asarray(matmul_q8_0(*args, block_m=8, block_n=8))
    b = np.asarray(matmul_q8_0(*args, block_m=32, block_n=16))
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_adversarial_extremes():
    # All-max-magnitude blocks: the 24-bit envelope case.
    w = np.full((8, 64), 3.0, dtype=np.float32)
    x = np.full((8, 64), -3.0, dtype=np.float32)
    wq, wd = quantize_q8_0(w)
    xq, xd = quantize_q8_0(x)
    got = np.asarray(matmul_q8_0(jnp.asarray(wq), jnp.asarray(wd), jnp.asarray(xq), jnp.asarray(xd)))
    np.testing.assert_allclose(got, np.full((8, 8), -9.0 * 64), rtol=1e-3)


def test_zero_inputs():
    wq, wd = quantize_q8_0(np.zeros((8, 64), np.float32))
    xq, xd = quantize_q8_0(np.zeros((8, 64), np.float32))
    got = np.asarray(matmul_q8_0(jnp.asarray(wq), jnp.asarray(wd), jnp.asarray(xq), jnp.asarray(xd)))
    assert (got == 0).all()


def test_vmem_budget_of_default_blocks():
    # Default tiling must fit a TPU core's ~16 MiB VMEM with huge margin.
    assert vmem_bytes(32, 32, 4096) < 1 << 20


@pytest.mark.parametrize("kb", [1, 2, 4])
def test_q8_k_quantizer_anchor(kb):
    x = rnd((2, 256 * kb), 21)
    q, d = quantize_q8_k(x)
    assert q.min() >= -128 and q.max() <= 127
    # The max-magnitude element must sit at -128 exactly.
    xb = x.reshape(2, kb, 256)
    qb = q.reshape(2, kb, 256)
    for r in range(2):
        for b in range(kb):
            idx = np.abs(xb[r, b]).argmax()
            assert qb[r, b, idx] == -128
