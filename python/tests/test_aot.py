"""AOT export tests: HLO text artifacts parse and contain the entry."""

import os
import subprocess
import sys


def test_aot_writes_parseable_hlo(tmp_path):
    out = tmp_path / "model.hlo.txt"
    env = dict(os.environ)
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out)],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert r.returncode == 0, r.stderr
    for name in ["model.hlo.txt", "q8_0_matmul.hlo.txt", "q3k_matmul.hlo.txt", "f16_matmul.hlo.txt"]:
        path = tmp_path / name
        assert path.exists(), name
        text = path.read_text()
        assert text.startswith("HloModule"), f"{name} must be HLO text"
        assert "ENTRY" in text
