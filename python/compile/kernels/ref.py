"""Pure-jnp oracles for the quantized dot-product kernels.

These are the L1 correctness references: NumPy/jnp implementations of the
GGML block formats (Q8_0 and the IMAX-restructured Q3_K) that the Pallas
kernels in q8_0.py / q3_k.py must match exactly in dequantized arithmetic.
They mirror rust/src/ggml (the L3 host reference) — quantization happens
on the rust side at runtime; here blocks arrive already decomposed into
integer arrays + scales, which is also how they stream into IMAX's LMM.
"""

import jax.numpy as jnp

QK8_0 = 32
QK_K = 256


def dequant_q8_0(qs, d):
    """Dequantize Q8_0 rows.

    qs: int8 [rows, k], d: float32 [rows, k // 32] per-block scales.
    """
    rows, k = qs.shape
    scales = jnp.repeat(d, QK8_0, axis=1)  # [rows, k]
    return qs.astype(jnp.float32) * scales


def matmul_q8_0(w_qs, w_d, x_qs, x_d):
    """Q8_0 x Q8_0 mat-mul oracle: out[n, m] = sum_k W[m,k] * X[n,k].

    Integer products accumulate per 32-block in int32 (the OP_SML8 /
    OP_AD24 path), then one f32 scale multiply per block pair — the same
    arithmetic as ggml's vec_dot_q8_0_q8_0 and the rust simulator.
    """
    m, k = w_qs.shape
    n, _ = x_qs.shape
    nb = k // QK8_0
    wq = w_qs.reshape(m, nb, QK8_0).astype(jnp.int32)
    xq = x_qs.reshape(n, nb, QK8_0).astype(jnp.int32)
    # isums[m, n, nb] = per-block integer dot.
    isums = jnp.einsum("mbk,nbk->mnb", wq, xq)
    scaled = isums.astype(jnp.float32) * w_d[:, None, :] * x_d[None, :, :]
    return scaled.sum(axis=-1).T  # [n, m]


def dequant_q3_imax(q3, scales5, d):
    """Dequantize IMAX-restructured Q3_K rows.

    q3: uint8 [rows, k] storing q+4 in [0, 7] (the OP_CVT53 3-bit stream),
    scales5: int8 [rows, k // 16] 5-bit scales (effective scale 2 * s5),
    d: float32 [rows, k // 256] super-block scales.
    """
    rows, k = q3.shape
    q = q3.astype(jnp.float32) - 4.0
    s = jnp.repeat(2.0 * scales5.astype(jnp.float32), 16, axis=1)
    dd = jnp.repeat(d, QK_K, axis=1)
    return q * s * dd


def matmul_q3_imax(w_q3, w_s5, w_d, x_qs, x_d):
    """IMAX Q3_K x Q8_K mat-mul oracle.

    x_qs: int8 [n, k] Q8_K quants, x_d: float32 [n, k // 256] scales.
    Per 16-element sub-block: int dot, times 2*s5, summed per super-block
    in int32, then one f32 multiply by (d_w * d_x).
    """
    m, k = w_q3.shape
    n, _ = x_qs.shape
    nsb = k // 16  # sub-blocks
    nb = k // QK_K
    wq = (w_q3.reshape(m, nsb, 16).astype(jnp.int32) - 4)
    xq = x_qs.reshape(n, nsb, 16).astype(jnp.int32)
    group = jnp.einsum("msk,nsk->mns", wq, xq)  # [m, n, nsb]
    scaled = group * (2 * w_s5.astype(jnp.int32))[:, None, :]
    isum = scaled.reshape(m, n, nb, QK_K // 16).sum(axis=-1)  # int32
    out = isum.astype(jnp.float32) * w_d[:, None, :] * x_d[None, :, :]
    return out.sum(axis=-1).T


def matmul_f16(w, x):
    """F16-weight mat-mul oracle (conv im2col path): out[n, m]."""
    return (x.astype(jnp.float32) @ w.astype(jnp.float32).T)
