"""L1 Pallas kernel: F16-weight mat-mul (the conv-im2col / VAE path).

Takes f32 inputs, rounds weights to bf16-on-MXU semantics (f16 storage in
GGML; the MXU computes bf16 x bf16 -> f32, so we model the f16 cast
explicitly and accumulate in f32). interpret=True as always.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, x_ref, o_ref):
    w = w_ref[...].astype(jnp.float16).astype(jnp.float32)
    x = x_ref[...]
    o_ref[...] = jax.lax.dot_general(
        x, w, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _fit(extent, target):
    """Largest divisor of `extent` not exceeding `target` (ragged shapes
    like the 77-token context get a smaller, evenly dividing block)."""
    for d in range(min(target, extent), 0, -1):
        if extent % d == 0:
            return d
    return 1


def matmul_f16(w, x, *, block_m=64, block_n=64):
    """out[n, m] = X[n, k] . W[m, k]^T with W rounded to f16."""
    m, k = w.shape
    n, _ = x.shape
    bm, bn = _fit(m, block_m), _fit(n, block_n)
    grid = (n // bn, m // bm)
    return pl.pallas_call(
        functools.partial(_kernel),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(w, x)
