"""L1 Pallas kernel: IMAX-restructured Q3_K x Q8_K mat-mul.

The operands arrive in the paper's OP_CVT53 representation (SS III-B): a
unified 3-bit quant stream (stored q+4) and 5-bit sub-block scales
(effective scale 2*s5) with the f16-ish super-block scale kept in f32.
The kernel:

* unpacks 3-bit -> signed int8 in VMEM (the CVT53 unpack path),
* runs the 16-element sub-block integer dots on the int8 MXU path with
  int32 accumulation (OP_SML8 / OP_AD24),
* weights each sub-block by its doubled 5-bit scale in integer domain
  (the CVT53 scale path), sums per super-block,
* applies one f32 multiply by d_w * d_x per super-block pair.

interpret=True always (CPU PJRT cannot run Mosaic custom-calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QK_K = 256
SUB = 16


def _kernel(q3_ref, s5_ref, wd_ref, xq_ref, xd_ref, o_ref, *, bm, bn, k):
    nsb = k // SUB
    nb = k // QK_K
    # CVT53 unpack: stored q+4 in [0,7] -> signed [-4,3].
    wq = (q3_ref[...].astype(jnp.int32) - 4).reshape(bm, nsb, SUB)
    xq = xq_ref[...].astype(jnp.int32).reshape(bn, nsb, SUB)
    group = jax.lax.dot_general(
        wq,
        xq,
        dimension_numbers=(((2,), (2,)), ((1,), (1,))),  # [nsb, bm, bn]
        preferred_element_type=jnp.int32,
    )
    # CVT53 scale path: x (2 * s5), still integer.
    s5 = (2 * s5_ref[...].astype(jnp.int32)).T  # [nsb, bm]
    scaled = group * s5[:, :, None]
    isum = scaled.reshape(nb, QK_K // SUB, bm, bn).sum(axis=1)  # [nb, bm, bn]
    wd = wd_ref[...].T[:, :, None]  # [nb, bm, 1]
    xd = xd_ref[...].T[:, None, :]  # [nb, 1, bn]
    out = (isum.astype(jnp.float32) * wd * xd).sum(axis=0)
    o_ref[...] = out.T  # [bn, bm]


def _fit(extent, target):
    """Largest divisor of `extent` not exceeding `target` (ragged shapes
    like the 77-token context get a smaller, evenly dividing block)."""
    for d in range(min(target, extent), 0, -1):
        if extent % d == 0:
            return d
    return 1


def matmul_q3_imax(w_q3, w_s5, w_d, x_qs, x_d, *, block_m=32, block_n=32):
    """out[n, m] for IMAX-restructured Q3_K weights x Q8_K activations.

    w_q3 int8 [m, k] (q+4), w_s5 int8 [m, k//16], w_d f32 [m, k//256],
    x_qs int8 [n, k], x_d f32 [n, k//256].
    """
    m, k = w_q3.shape
    n, _ = x_qs.shape
    nsb, nb = k // SUB, k // QK_K
    bm, bn = _fit(m, block_m), _fit(n, block_n)
    grid = (n // bn, m // bm)
    return pl.pallas_call(
        functools.partial(_kernel, bm=bm, bn=bn, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, nsb), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, nb), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, nb), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(w_q3, w_s5, w_d, x_qs, x_d)


def vmem_bytes(block_m, block_n, k):
    """VMEM footprint estimate of one grid step."""
    return (
        block_m * k  # 3-bit stream (byte-expanded in VMEM)
        + block_m * (k // SUB)  # 5-bit scales
        + 4 * block_m * (k // QK_K)
        + block_n * k
        + 4 * block_n * (k // QK_K)
        + 4 * block_m * block_n
    )
