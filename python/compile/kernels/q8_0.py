"""L1 Pallas kernel: Q8_0 x Q8_0 quantized mat-mul.

TPU adaptation of the paper's IMAX Q8_0 dataflow (Fig. 3) per DESIGN.md
#Hardware-Adaptation:

* the per-PE LMM staging becomes a BlockSpec-driven HBM->VMEM tile
  schedule (one (BM, K) weight tile + one (BN, K) activation tile
  resident per grid step);
* the OP_SML8 8-bit multiply-add chain aggregating into 24-bit integers
  becomes an int8 x int8 dot with a widened int32 accumulator
  (`preferred_element_type=jnp.int32` targets the MXU's integer path);
* the final f32 multiply by d_w * d_x per 32-block mirrors the shared
  FMA spine.

interpret=True always: the CPU PJRT client cannot execute Mosaic
custom-calls; real-TPU perf is estimated from the VMEM footprint and MXU
utilization in EXPERIMENTS.md #Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QK8_0 = 32


def _kernel(wq_ref, wd_ref, xq_ref, xd_ref, o_ref, *, bm, bn, k):
    nb = k // QK8_0
    wq = wq_ref[...].reshape(bm, nb, QK8_0)
    xq = xq_ref[...].reshape(bn, nb, QK8_0)
    # Per-block integer dot: contract the 32-lane axis with an int32
    # accumulator (OP_SML8 -> OP_AD24). dot_general batches over blocks.
    isums = jax.lax.dot_general(
        wq,
        xq,
        dimension_numbers=(((2,), (2,)), ((1,), (1,))),  # [nb, bm, bn]
        preferred_element_type=jnp.int32,
    )
    wd = wd_ref[...]  # [bm, nb]
    xd = xd_ref[...]  # [bn, nb]
    scaled = (
        isums.astype(jnp.float32)
        * wd.T[:, :, None]  # [nb, bm, 1]
        * xd.T[:, None, :]  # [nb, 1, bn]
    )
    o_ref[...] = scaled.sum(axis=0).T  # [bn, bm]


def _fit(extent, target):
    """Largest divisor of `extent` not exceeding `target` (ragged shapes
    like the 77-token context get a smaller, evenly dividing block)."""
    for d in range(min(target, extent), 0, -1):
        if extent % d == 0:
            return d
    return 1


def matmul_q8_0(w_qs, w_d, x_qs, x_d, *, block_m=32, block_n=32):
    """out[n, m] = sum_k W[m, k] * X[n, k], Q8_0-quantized operands.

    w_qs int8 [m, k], w_d f32 [m, k//32], x_qs int8 [n, k], x_d f32
    [n, k//32]. m, n must divide by the block sizes (pad upstream).
    """
    m, k = w_qs.shape
    n, _ = x_qs.shape
    nb = k // QK8_0
    bm, bn = _fit(m, block_m), _fit(n, block_n)
    grid = (n // bn, m // bm)
    return pl.pallas_call(
        functools.partial(_kernel, bm=bm, bn=bn, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, nb), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, nb), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(w_qs, w_d, x_qs, x_d)


def vmem_bytes(block_m, block_n, k):
    """VMEM footprint estimate of one grid step (perf model input)."""
    nb = k // QK8_0
    return (
        block_m * k  # int8 weight tile
        + block_n * k  # int8 activation tile
        + 4 * (block_m * nb + block_n * nb)  # scales
        + 4 * block_m * block_n  # f32 out tile
    )
