"""NumPy reference quantizers (build/test-time only).

Mirror rust/src/ggml's quantize_row_* so python tests can fabricate the
same block decompositions the rust runtime sends to the artifacts.
"""

import numpy as np

QK8_0 = 32
QK_K = 256


def quantize_q8_0(x):
    """x: [rows, k] f32 -> (qs int8 [rows,k], d f32 [rows, k//32])."""
    rows, k = x.shape
    xb = x.reshape(rows, k // QK8_0, QK8_0)
    amax = np.abs(xb).max(axis=-1)
    d = amax / 127.0
    inv = np.where(d > 0, 1.0 / np.where(d > 0, d, 1.0), 0.0)
    q = np.round(xb * inv[..., None]).clip(-127, 127).astype(np.int8)
    return q.reshape(rows, k), d.astype(np.float32)


def quantize_q8_k(x):
    """x: [rows, k] f32 -> (qs int8 [rows,k], d f32 [rows, k//256]).

    GGML's quantize_row_q8_K: the max-magnitude value anchors at -128.
    """
    rows, k = x.shape
    xb = x.reshape(rows, k // QK_K, QK_K)
    idx = np.abs(xb).argmax(axis=-1)
    maxv = np.take_along_axis(xb, idx[..., None], axis=-1)[..., 0]
    iscale = np.where(maxv != 0, -128.0 / np.where(maxv != 0, maxv, 1.0), 0.0)
    q = np.round(xb * iscale[..., None]).clip(-128, 127).astype(np.int8)
    d = np.where(iscale != 0, 1.0 / np.where(iscale != 0, iscale, 1.0), 0.0)
    return q.reshape(rows, k), d.astype(np.float32)


def quantize_q3_imax(x):
    """x: [rows, k] -> IMAX-restructured Q3_K decomposition.

    Returns (q3 uint8 [rows,k] storing q+4, s5 int8 [rows,k//16],
    d f32 [rows,k//256]). Simplified quantizer (no rmse refinement):
    per-16 scale from max|x|/4, 6-bit coded against the super-block max,
    then rounded to 5 bits — the OP_CVT53 representation.
    """
    rows, k = x.shape
    nsb = k // 16
    xs = x.reshape(rows, nsb, 16)
    amax = np.abs(xs).max(axis=-1)
    # Value with the largest magnitude decides the sign (make_q3_quants).
    idx = np.abs(xs).argmax(axis=-1)
    maxv = np.take_along_axis(xs, idx[..., None], axis=-1)[..., 0]
    sub_scale = np.where(maxv != 0, -maxv / 4.0, 0.0)  # = 1/iscale

    nb = k // QK_K
    ss = sub_scale.reshape(rows, nb, QK_K // 16)
    aidx = np.abs(ss).argmax(axis=-1)
    max_scale = np.take_along_axis(ss, aidx[..., None], axis=-1)[..., 0]
    d = np.where(max_scale != 0, -max_scale / 32.0, 0.0).astype(np.float32)

    coded = np.zeros((rows, nb, QK_K // 16), dtype=np.int8)
    nz = d != 0
    coded_f = np.where(d[..., None] != 0, ss / np.where(d[..., None] != 0, d[..., None], 1.0), 0.0)
    coded = np.round(coded_f).clip(-32, 31).astype(np.int8)
    # 5-bit approximation: round-half-away division by 2.
    s5 = np.sign(coded) * ((np.abs(coded.astype(np.int32)) + 1) // 2)
    s5 = s5.clip(-16, 15).astype(np.int8).reshape(rows, nsb)

    eff = 2.0 * s5.reshape(rows, nb, QK_K // 16).astype(np.float32) * d[..., None]
    eff_rep = np.repeat(eff.reshape(rows, nsb), 16, axis=1).reshape(rows, nsb, 16)
    with np.errstate(divide="ignore", invalid="ignore"):
        q = np.where(eff_rep != 0, xs / np.where(eff_rep != 0, eff_rep, 1.0), 0.0)
    q3 = (np.round(q).clip(-4, 3) + 4).astype(np.uint8).reshape(rows, k)
    _ = nz
    return q3, s5, d
