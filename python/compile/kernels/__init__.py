"""L1 Pallas kernels + references for the IMAX-SD reproduction."""
