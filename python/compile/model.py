"""L2: the jax compute graph — a mini SD transformer block with quantized
linears, calling the L1 Pallas kernels.

This is the U-Net bottleneck of the rust pipeline expressed in jax:
self-attention + cross-attention to the 77-token text context + gated
feed-forward, with every eligible linear weight Q8_0-quantized at build
time (baked into the HLO as constants) and executed through
kernels.q8_0.matmul_q8_0 — so the exported artifact exercises exactly
the offloaded arithmetic. Attention scores stay f32 (sd.cpp policy) and
the projection uses the f16 kernel.

Python runs ONLY at build time: aot.py lowers `transformer_block` once to
HLO text and the rust runtime executes it thereafter.
"""

import numpy as np
import jax.numpy as jnp

from .kernels.f16_dot import matmul_f16
from .kernels.q8_0 import matmul_q8_0
from .kernels.quantize import quantize_q8_0

SEQ = 64        # 8x8 bottleneck tokens
DIM = 256       # transformer width (k-quant eligible)
CTX_LEN = 77    # text tokens
HEADS = 4


def _weights(seed):
    """Synthesize + quantize the block's weights (build-time only)."""
    r = np.random.RandomState(seed)

    def lin(dout, din):
        w = (r.randn(dout, din) / np.sqrt(din)).astype(np.float32)
        qs, d = quantize_q8_0(w)
        return jnp.asarray(qs), jnp.asarray(d)

    return {
        "q": lin(DIM, DIM),
        "k": lin(DIM, DIM),
        "v": lin(DIM, DIM),
        "o": lin(DIM, DIM),
        "xq": lin(DIM, DIM),
        "xk": lin(DIM, DIM),
        "xv": lin(DIM, DIM),
        "xo": lin(DIM, DIM),
        "ff1": lin(2 * DIM, DIM),
        "ff2": lin(DIM, DIM),
        # proj stays f16 (the conv-ish path).
        "proj": jnp.asarray((r.randn(DIM, DIM) / np.sqrt(DIM)).astype(np.float32)),
    }


def _qmm(w, x):
    """Quantized linear: quantize activations to Q8_0, run the kernel."""
    # Activation quantization in jnp (the host marshalling step).
    n, k = x.shape
    xb = x.reshape(n, k // 32, 32)
    amax = jnp.abs(xb).max(axis=-1)
    d = amax / 127.0
    inv = jnp.where(d > 0, 1.0 / jnp.where(d > 0, d, 1.0), 0.0)
    q = jnp.clip(jnp.round(xb * inv[..., None]), -127, 127).astype(jnp.int8)
    return matmul_q8_0(w[0], w[1], q.reshape(n, k), d)


def _attention(q, k, v):
    hd = DIM // HEADS
    outs = []
    for h in range(HEADS):
        qh = q[:, h * hd:(h + 1) * hd]
        kh = k[:, h * hd:(h + 1) * hd]
        vh = v[:, h * hd:(h + 1) * hd]
        s = (qh @ kh.T) / np.sqrt(hd)
        a = jnp.exp(s - s.max(axis=-1, keepdims=True))
        a = a / a.sum(axis=-1, keepdims=True)
        outs.append(a @ vh)
    return jnp.concatenate(outs, axis=-1)


def make_transformer_block(seed=0x51D):
    """Returns fn(x [SEQ, DIM], ctx [CTX_LEN, DIM]) -> [SEQ, DIM]."""
    w = _weights(seed)

    def block(x, ctx):
        h = matmul_f16(w["proj"], x)                      # f16 proj-in
        # Self-attention.
        a = _attention(_qmm(w["q"], h), _qmm(w["k"], h), _qmm(w["v"], h))
        h = h + _qmm(w["o"], a)
        # Cross-attention.
        a = _attention(_qmm(w["xq"], h), _qmm(w["xk"], ctx), _qmm(w["xv"], ctx))
        h = h + _qmm(w["xo"], a)
        # Gated FF (GEGLU-style).
        m = _qmm(w["ff1"], h)
        val, gate = m[:, :DIM], m[:, DIM:]
        g = 0.5 * gate * (1.0 + jnp.tanh(0.7978845608 * (gate + 0.044715 * gate**3)))
        h = h + _qmm(w["ff2"], val * g)
        return (h,)

    return block


def make_q8_0_matmul(m, n, k):
    """Standalone Q8_0 mat-mul entry (kernel-artifact for the runtime)."""

    def fn(wq, wd, xq, xd):
        return (matmul_q8_0(wq, wd, xq, xd, block_m=min(32, m), block_n=min(32, n)),)

    return fn


def make_q3_imax_matmul(m, n, k):
    """Standalone IMAX-Q3_K mat-mul entry."""
    from .kernels.q3_k import matmul_q3_imax

    def fn(q3, s5, wd, xq, xd):
        return (matmul_q3_imax(q3, s5, wd, xq, xd, block_m=min(32, m), block_n=min(32, n)),)

    return fn


def make_f16_matmul(m, n, k):
    """Standalone F16 mat-mul entry."""

    def fn(w, x):
        return (matmul_f16(w, x, block_m=min(64, m), block_n=min(64, n)),)

    return fn
