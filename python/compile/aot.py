"""AOT export: lower every L2 entry point to HLO TEXT artifacts.

HLO *text* (never `.serialize()`): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which the rust side's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md and gen_hlo.py).

Usage: python -m compile.aot --out ../artifacts/model.hlo.txt
Writes the main model artifact at --out plus the kernel artifacts next
to it. Python never runs after this step.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: without it the text printer elides big baked
    # weight constants as `constant({...})`, which the 0.5.1 parser reads
    # back as ZEROS — silently corrupting the model artifact.
    return comp.as_hlo_text(print_large_constants=True)


def export(fn, args, path):
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {len(text):>9} chars  {path}")
    return text


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# Fixed artifact shapes (the rust runtime matches these).
Q8_M, Q8_N, Q8_K = 64, 32, 256
Q3_M, Q3_N, Q3_K = 32, 16, 512
F16_M, F16_N, F16_K = 64, 64, 288


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/model.hlo.txt")
    args = ap.parse_args()
    outdir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(outdir, exist_ok=True)

    # Main model artifact: the quantized transformer block.
    block = model.make_transformer_block()
    export(
        block,
        (spec((model.SEQ, model.DIM), jnp.float32),
         spec((model.CTX_LEN, model.DIM), jnp.float32)),
        args.out,
    )

    # Kernel artifacts.
    export(
        model.make_q8_0_matmul(Q8_M, Q8_N, Q8_K),
        (spec((Q8_M, Q8_K), jnp.int8), spec((Q8_M, Q8_K // 32), jnp.float32),
         spec((Q8_N, Q8_K), jnp.int8), spec((Q8_N, Q8_K // 32), jnp.float32)),
        os.path.join(outdir, "q8_0_matmul.hlo.txt"),
    )
    export(
        model.make_q3_imax_matmul(Q3_M, Q3_N, Q3_K),
        (spec((Q3_M, Q3_K), jnp.int8), spec((Q3_M, Q3_K // 16), jnp.int8),
         spec((Q3_M, Q3_K // 256), jnp.float32),
         spec((Q3_N, Q3_K), jnp.int8), spec((Q3_N, Q3_K // 256), jnp.float32)),
        os.path.join(outdir, "q3k_matmul.hlo.txt"),
    )
    export(
        model.make_f16_matmul(F16_M, F16_N, F16_K),
        (spec((F16_M, F16_K), jnp.float32), spec((F16_N, F16_K), jnp.float32)),
        os.path.join(outdir, "f16_matmul.hlo.txt"),
    )


if __name__ == "__main__":
    main()
