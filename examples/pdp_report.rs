//! Energy-efficiency report: regenerate the paper's PDP analysis (Fig. 8)
//! plus a what-if sweep of the IMAX ASIC power model over active-unit
//! counts — the "AI-specialized CGLA" design-space hint the conclusion
//! points at.
//!
//! Run: `cargo run --release --example pdp_report`

use imax_sd::device::{arm_a72, gtx_1080ti, pdp_joules, xeon_w5, Device, ImaxDevice};
use imax_sd::imax::power::asic_power_units;
use imax_sd::sd::arch::sd_turbo_512;
use imax_sd::sd::QuantModel;
use imax_sd::util::tables::Table;

fn main() {
    let trace = sd_turbo_512(1);
    let mut t = Table::new(
        "PDP report (one 512x512 SD-Turbo generation)",
        &["Device", "Q3_K e2e (s)", "Q3_K PDP (kJ)", "Q8_0 e2e (s)", "Q8_0 PDP (kJ)"],
    );
    let devs: Vec<Box<dyn Device>> = vec![
        Box::new(arm_a72()),
        Box::new(ImaxDevice::fpga(1)),
        Box::new(ImaxDevice::asic(1)),
        Box::new(xeon_w5()),
        Box::new(gtx_1080ti()),
    ];
    for d in &devs {
        let q3 = pdp_joules(d.as_ref(), &trace, QuantModel::Q3K);
        let q8 = pdp_joules(d.as_ref(), &trace, QuantModel::Q8_0);
        t.row(&[
            d.name(),
            format!("{:.1}", q3.seconds),
            format!("{:.2}", q3.joules / 1e3),
            format!("{:.1}", q8.seconds),
            format!("{:.2}", q8.joules / 1e3),
        ]);
    }
    t.print();

    println!("\nASIC power vs active functional units (the specialization axis):");
    for units in [32usize, 46, 51, 64] {
        println!("  {units:>2} units -> {:.1} W", asic_power_units(units));
    }
    println!("\npaper findings reproduced: ARM lowest PDP; ASIC < Xeon on both models;");
    println!("ASIC < GPU on Q3_K. Deviation: our model also gives ASIC < GPU on Q8_0");
    println!("(see EXPERIMENTS.md for the attribution).");
}
