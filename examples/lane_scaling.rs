//! Lane-scaling demo on the *mechanical* coordinator: dispatch a batch
//! of quantized mat-mul jobs across 1–8 simulated lanes with a 2-thread
//! host pool and watch wall-clock + simulated-cycle scaling saturate —
//! the §V-A host-bottleneck effect, reproduced with real threads rather
//! than the analytic model.
//!
//! Run: `cargo run --release --example lane_scaling`

use imax_sd::coordinator::{Coordinator, OffloadPolicy};
use imax_sd::coordinator::scheduler::make_job;
use imax_sd::ggml::{DType, Tensor};
use imax_sd::imax::ImaxConfig;
use imax_sd::util::rng::Xoshiro256pp;
use imax_sd::util::tables::Table;

fn random(rows: usize, cols: usize, seed: u64) -> Tensor {
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    let mut v = vec![0.0f32; rows * cols];
    r.fill_normal(&mut v, 0.5);
    Tensor::f32(rows, cols, v)
}

fn main() {
    let jobs: Vec<_> = (0..24)
        .map(|i| {
            make_job(
                &format!("layer{i}"),
                random(64, 512, 100 + i),
                DType::Q8_0,
                random(48, 512, 200 + i),
            )
        })
        .collect();
    let mut t = Table::new(
        "Coordinator lane scaling (24 Q8_0 jobs, 2 host threads — the A72 pair)",
        &["lanes", "wall ms", "speedup", "sim Mcycles", "offloaded"],
    );
    let mut base = None;
    for lanes in [1usize, 2, 3, 4, 6, 8] {
        let c = Coordinator::new(ImaxConfig::fpga(1), lanes, 2, OffloadPolicy::QuantizedOnly);
        let t0 = std::time::Instant::now();
        // A 2-thread host pool pulling jobs through the submission path
        // (the pool the removed `execute_batch` used to spawn): the host
        // threads do the marshalling, so they are the supply ceiling.
        let next = std::sync::atomic::AtomicUsize::new(0);
        let done = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..c.host_threads {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= jobs.len() {
                        break;
                    }
                    let out = c.execute(&jobs[i]);
                    assert_eq!((out.rows, out.cols), (jobs[i].x.rows, jobs[i].w.rows));
                    done.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                });
            }
        });
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(done.load(std::sync::atomic::Ordering::Relaxed), jobs.len());
        let base_v = *base.get_or_insert(wall);
        t.row(&[
            format!("{lanes}"),
            format!("{:.1}", wall * 1e3),
            format!("{:.2}x", base_v / wall),
            format!(
                "{:.1}",
                c.metrics.imax_cycles.load(std::sync::atomic::Ordering::Relaxed) as f64 / 1e6
            ),
            format!("{}", c.metrics.offloaded_jobs.load(std::sync::atomic::Ordering::Relaxed)),
        ]);
    }
    t.print();
    println!("\nnote: with only 2 host threads marshalling, speedup saturates near 2 —");
    println!("the same dual-core supply ceiling the paper reports in §V-A.");
}
