//! Lane-scaling demo on the *mechanical* coordinator: dispatch a batch
//! of quantized mat-mul jobs across 1–8 simulated lanes with a 2-thread
//! host pool and watch wall-clock + simulated-cycle scaling saturate —
//! the §V-A host-bottleneck effect, reproduced with real threads rather
//! than the analytic model.
//!
//! Run: `cargo run --release --example lane_scaling`

use imax_sd::coordinator::{Coordinator, OffloadPolicy};
use imax_sd::coordinator::scheduler::make_job;
use imax_sd::ggml::{DType, Tensor};
use imax_sd::imax::ImaxConfig;
use imax_sd::util::rng::Xoshiro256pp;
use imax_sd::util::tables::Table;

fn random(rows: usize, cols: usize, seed: u64) -> Tensor {
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    let mut v = vec![0.0f32; rows * cols];
    r.fill_normal(&mut v, 0.5);
    Tensor::f32(rows, cols, v)
}

fn main() {
    let jobs: Vec<_> = (0..24)
        .map(|i| {
            make_job(
                &format!("layer{i}"),
                random(64, 512, 100 + i),
                DType::Q8_0,
                random(48, 512, 200 + i),
            )
        })
        .collect();
    let mut t = Table::new(
        "Coordinator lane scaling (24 Q8_0 jobs, 2 host threads — the A72 pair)",
        &["lanes", "wall ms", "speedup", "sim Mcycles", "offloaded"],
    );
    let mut base = None;
    for lanes in [1usize, 2, 3, 4, 6, 8] {
        let c = Coordinator::new(ImaxConfig::fpga(1), lanes, 2, OffloadPolicy::QuantizedOnly);
        let t0 = std::time::Instant::now();
        let outs = c.execute_batch(&jobs);
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(outs.len(), jobs.len());
        let base_v = *base.get_or_insert(wall);
        t.row(&[
            format!("{lanes}"),
            format!("{:.1}", wall * 1e3),
            format!("{:.2}x", base_v / wall),
            format!(
                "{:.1}",
                c.metrics.imax_cycles.load(std::sync::atomic::Ordering::Relaxed) as f64 / 1e6
            ),
            format!("{}", c.metrics.offloaded_jobs.load(std::sync::atomic::Ordering::Relaxed)),
        ]);
    }
    t.print();
    println!("\nnote: with only 2 host threads marshalling, speedup saturates near 2 —");
    println!("the same dual-core supply ceiling the paper reports in §V-A.");
}
