//! Open-loop load generator for the HTTP serving front-end.
//!
//! Starts an in-process [`Server`] on an ephemeral loopback port, then
//! drives it the way a real client fleet would — every request is a
//! full HTTP round-trip (`POST /predictions` → poll → terminal state):
//!
//! 1. **baseline** — sequential requests to warm the weight pools and
//!    the runner's EWMA batch-time estimate.
//! 2. **poisson** — open-loop Poisson arrivals (inter-arrival
//!    `-ln(u)/λ`) at offered loads of 0.5×, 2× and 6× the measured
//!    service capacity. At 6× the bounded queue must shed with 429s
//!    while the p99 latency of *admitted* requests stays inside the
//!    end-to-end SLO — backpressure protects the admitted tail.
//! 3. **burst** — every request arrives at once (the worst arrival
//!    process for a queue estimator).
//! 4. **mixed** — step counts drawn from {1, 1, 1, 2, 4}, exercising
//!    the step-homogeneous batcher under heterogeneous work.
//! 5. **webhook** — predictions registering a callback URL against a
//!    fault-injecting loopback receiver (a scripted 503 forces the
//!    retry/backoff path); after the drain, deliveries must equal the
//!    admitted terminal predictions exactly, with zero dead letters.
//!
//! Offered loads and SLOs scale from the *measured* EWMA service time,
//! so the shedding/tail assertions hold on fast and slow machines
//! alike — and the measured service time also seeds the runner's
//! cold-start admission prior. Emits `BENCH_serve_http.json`, one
//! record per phase plus the webhook delivery counters.
//!
//! `--smoke` shrinks every phase for CI and adds a cancellation
//! round-trip plus a signal-driven graceful shutdown check.

use imax_sd::sd::pipeline::{Backend, PipelineConfig};
use imax_sd::sd::QuantModel;
use imax_sd::serve::{RunnerState, ServeConfig, ServeHarness, WebhookStats};
use imax_sd::server::http::http_call;
use imax_sd::server::{shutdown, Fault, FaultReceiver, Json, RunnerConfig, Server, WebhookConfig};
use imax_sd::util::rng::Xoshiro256pp;
use imax_sd::util::stats::percentile;
use imax_sd::util::tables::Table;
use std::time::{Duration, Instant};

/// One client's view of one request.
enum Outcome {
    /// Admitted and reached a terminal state.
    Finished { latency_seconds: f64, state: String },
    /// 429 at admission.
    Rejected,
    /// 503 (draining) or a transport/protocol failure.
    Error,
}

/// Aggregate for one phase of the run.
struct PhaseRecord {
    phase: String,
    offered_rps: f64,
    requests: usize,
    admitted: usize,
    succeeded: usize,
    rejected: usize,
    errors: usize,
    p50_seconds: f64,
    p99_seconds: f64,
    slo_seconds: f64,
}

impl PhaseRecord {
    fn rejection_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.rejected as f64 / self.requests as f64
        }
    }

    fn json(&self) -> Json {
        Json::obj(vec![
            ("phase", Json::Str(self.phase.clone())),
            ("offered_rps", Json::Num(self.offered_rps)),
            ("requests", Json::Num(self.requests as f64)),
            ("admitted", Json::Num(self.admitted as f64)),
            ("succeeded", Json::Num(self.succeeded as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("rejection_rate", Json::Num(self.rejection_rate())),
            ("p50_seconds", Json::Num(self.p50_seconds)),
            ("p99_seconds", Json::Num(self.p99_seconds)),
            ("slo_seconds", Json::Num(self.slo_seconds)),
        ])
    }
}

/// POST one prediction and poll it to a terminal state; `webhook`
/// additionally registers a completion callback URL.
fn submit_and_wait(
    addr: &str,
    prompt: &str,
    seed: u64,
    steps: usize,
    webhook: Option<&str>,
) -> Outcome {
    let mut fields = vec![
        ("prompt", Json::Str(prompt.into())),
        ("seed", Json::Num(seed as f64)),
        ("steps", Json::Num(steps as f64)),
    ];
    if let Some(url) = webhook {
        fields.push(("webhook", Json::Str(url.into())));
    }
    let body = Json::obj(fields);
    let t0 = Instant::now();
    let Ok(created) = http_call(addr, "POST", "/predictions", Some(&body)) else {
        return Outcome::Error;
    };
    if created.status == 429 {
        return Outcome::Rejected;
    }
    if created.status != 202 {
        return Outcome::Error;
    }
    let Some(id) = created.json().ok().and_then(|j| j.get("id").and_then(Json::as_u64)) else {
        return Outcome::Error;
    };
    // Bounded poll: 2 ms cadence, 120 s cap.
    for _ in 0..60_000 {
        let Ok(poll) = http_call(addr, "GET", &format!("/predictions/{id}"), None) else {
            return Outcome::Error;
        };
        if let Ok(st) = poll.json() {
            let state = st.get("status").and_then(Json::as_str).unwrap_or("").to_string();
            let terminal = matches!(
                state.as_str(),
                s if s == RunnerState::Succeeded.name()
                    || s == RunnerState::Failed.name()
                    || s == RunnerState::Cancelled.name()
                    || s == RunnerState::Expired.name()
            );
            if terminal {
                return Outcome::Finished { latency_seconds: t0.elapsed().as_secs_f64(), state };
            }
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    Outcome::Error
}

/// Run one phase: spawn a client thread per arrival, spaced by
/// `gaps[i]`, and fold the outcomes into a record.
fn run_phase(
    addr: &str,
    phase: &str,
    offered_rps: f64,
    gaps: &[Duration],
    steps: &[usize],
    slo_seconds: f64,
    webhook: Option<&str>,
) -> PhaseRecord {
    let mut clients = Vec::new();
    for (i, gap) in gaps.iter().enumerate() {
        let addr = addr.to_string();
        let step_count = steps[i % steps.len()];
        let prompt = format!("load-gen request {i}");
        let webhook = webhook.map(str::to_string);
        clients.push(std::thread::spawn(move || {
            submit_and_wait(&addr, &prompt, 1000 + i as u64, step_count, webhook.as_deref())
        }));
        std::thread::sleep(*gap);
    }
    let (mut admitted, mut succeeded, mut rejected, mut errors) = (0usize, 0usize, 0usize, 0usize);
    let mut latencies = Vec::new();
    for c in clients {
        match c.join().expect("client thread panicked") {
            Outcome::Finished { latency_seconds, state } => {
                admitted += 1;
                if state == RunnerState::Succeeded.name() {
                    succeeded += 1;
                    latencies.push(latency_seconds);
                }
            }
            Outcome::Rejected => rejected += 1,
            Outcome::Error => errors += 1,
        }
    }
    // total_cmp: a NaN latency (impossible today, but Instant math has
    // betrayed better programs) must not panic the whole run.
    latencies.sort_by(f64::total_cmp);
    let (p50, p99) = if latencies.is_empty() {
        (0.0, 0.0)
    } else {
        (percentile(&latencies, 50.0), percentile(&latencies, 99.0))
    };
    PhaseRecord {
        phase: phase.to_string(),
        offered_rps,
        requests: gaps.len(),
        admitted,
        succeeded,
        rejected,
        errors,
        p50_seconds: p50,
        p99_seconds: p99,
        slo_seconds,
    }
}

/// Poisson inter-arrival gaps at `rps`, deterministic per phase seed.
fn poisson_gaps(n: usize, rps: f64, seed: u64) -> Vec<Duration> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    (0..n)
        .map(|_| {
            let u = (1.0 - rng.next_f64()).max(1e-12); // (0, 1], ln is finite
            Duration::from_secs_f64(-u.ln() / rps)
        })
        .collect()
}

fn smoke_cancel_round_trip(addr: &str) {
    // A many-step request cancelled right after creation must reach a
    // terminal state without running to completion.
    let body = Json::obj(vec![
        ("prompt", Json::Str("cancel me".into())),
        ("steps", Json::Num(8.0)),
    ]);
    let created = http_call(addr, "POST", "/predictions", Some(&body)).expect("create");
    assert_eq!(created.status, 202, "cancel target admitted");
    let id = created.json().unwrap().get("id").unwrap().as_u64().unwrap();
    let cancelled = http_call(addr, "POST", &format!("/predictions/{id}/cancel"), None).unwrap();
    assert_eq!(cancelled.status, 200, "cancel route answers");
    for _ in 0..5_000 {
        let st = http_call(addr, "GET", &format!("/predictions/{id}"), None).unwrap();
        let state = st.json().unwrap().get("status").unwrap().as_str().unwrap().to_string();
        if state == RunnerState::Cancelled.name() {
            println!("cancel round-trip: request {id} reached '{state}'");
            return;
        }
        assert_ne!(state, RunnerState::Succeeded.name(), "cancelled request ran to completion");
        std::thread::sleep(Duration::from_millis(2));
    }
    panic!("cancelled request never reached a terminal state");
}

fn webhook_json(wh: &WebhookStats) -> Json {
    let mut fields = vec![
        ("enqueued", Json::Num(wh.enqueued as f64)),
        ("attempts", Json::Num(wh.attempts as f64)),
        ("delivered", Json::Num(wh.delivered as f64)),
        ("retries", Json::Num(wh.retries as f64)),
        ("dead_lettered", Json::Num(wh.dead_lettered as f64)),
        ("overflowed", Json::Num(wh.overflowed as f64)),
    ];
    if let Some(lat) = wh.latency_summary() {
        fields.push((
            "delivery_latency_seconds",
            Json::obj(vec![
                ("p50", Json::Num(lat.median)),
                ("p95", Json::Num(lat.p95)),
                ("p99", Json::Num(lat.p99)),
            ]),
        ));
    }
    Json::obj(fields)
}

fn emit_json(records: &[PhaseRecord], service_seconds: f64, capacity_rps: f64, wh: &WebhookStats) {
    let body = Json::obj(vec![
        ("bench", Json::Str("serve_http".into())),
        ("service_seconds_ewma", Json::Num(service_seconds)),
        ("capacity_rps", Json::Num(capacity_rps)),
        ("phases", Json::Arr(records.iter().map(PhaseRecord::json).collect())),
        ("webhook", webhook_json(wh)),
    ]);
    let path = "BENCH_serve_http.json";
    std::fs::write(path, body.render() + "\n").expect("write bench json");
    println!("wrote {path} ({} phases)", records.len());
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let workers = 2usize;
    let max_batch = 2usize;
    let harness = ServeHarness::new(
        PipelineConfig {
            weight_seed: 99,
            model: Some(QuantModel::Q8_0),
            steps: 1,
            backend: Backend::Host { threads: 2 },
            conv_offload: false,
        },
        ServeConfig {
            lanes: 1,
            host_threads: 2,
            max_batch,
            workers,
            sharded: false,
            queue_capacity: 8,
        },
    );

    // The runner's SLO is fixed at start, but offered loads must scale
    // from the measured service time — so a throwaway probe server with
    // an infinite SLO measures it first.
    let probe = Server::start(
        "127.0.0.1:0",
        harness,
        RunnerConfig {
            slo_seconds: f64::INFINITY,
            default_steps: 1,
            max_steps: 8,
            ..RunnerConfig::default()
        },
    )
    .expect("bind probe server");
    let probe_addr = probe.addr().to_string();
    let n_base = if smoke { 2 } else { 4 };
    for i in 0..n_base {
        match submit_and_wait(&probe_addr, &format!("baseline {i}"), i as u64, 1, None) {
            Outcome::Finished { .. } => {}
            _ => panic!("baseline request failed"),
        }
    }
    let service_seconds = probe.runner().ewma_batch_seconds().max(1e-3);
    probe.shutdown();

    // Admission threshold at 5 service times; the end-to-end SLO the
    // admitted tail is held to is 3x that (queue wait bounded by the
    // admission threshold, plus concurrent service and estimator slack
    // — the baseline EWMA is measured without worker contention).
    let slo_admit = 5.0 * service_seconds;
    let slo_e2e = 3.0 * slo_admit;
    let capacity_rps = workers as f64 * max_batch as f64 / service_seconds;
    println!(
        "load_gen: service {:.1} ms, capacity {:.1} req/s, SLO admit {:.1} / e2e {:.1} ms{}",
        service_seconds * 1e3,
        capacity_rps,
        slo_admit * 1e3,
        slo_e2e * 1e3,
        if smoke { " [smoke]" } else { "" }
    );

    let harness = ServeHarness::new(
        PipelineConfig {
            weight_seed: 99,
            model: Some(QuantModel::Q8_0),
            steps: 1,
            backend: Backend::Host { threads: 2 },
            conv_offload: false,
        },
        ServeConfig {
            lanes: 1,
            host_threads: 2,
            max_batch,
            workers,
            sharded: false,
            queue_capacity: 8,
        },
    );
    let server = Server::start(
        "127.0.0.1:0",
        harness,
        RunnerConfig {
            slo_seconds: slo_admit,
            default_steps: 1,
            max_steps: 8,
            // The probe measured the real service time: use it as the
            // cold-start admission prior instead of the static default.
            cold_start_prior_seconds: service_seconds,
            // Fast schedule against a loopback receiver (the pinned
            // smoke vectors in `backoff_schedule_is_pinned` use these).
            webhook: WebhookConfig {
                base_backoff_ms: 10,
                max_backoff_ms: 50,
                jitter_seed: 7,
                max_attempts: 3,
                ..WebhookConfig::default()
            },
        },
    )
    .expect("bind server");
    let addr = server.addr().to_string();

    let mut records = Vec::new();

    // Re-warm this server's EWMA so admission estimates are live from
    // the first timed phase.
    let warm = if smoke { 2 } else { 4 };
    records.push(run_phase(
        &addr,
        "baseline",
        0.0,
        &vec![Duration::from_millis(1); warm],
        &[1],
        slo_e2e,
        None,
    ));

    // The overload phase always offers enough arrivals to overflow the
    // queue bound (8 waiting + 4 in flight): shed before it, the 429s
    // never happen and the assertion below rightly fails.
    let n_low = if smoke { 4 } else { 16 };
    for (label, mult, n) in [
        ("poisson_0.5x", 0.5, n_low),
        ("poisson_2x", 2.0, n_low),
        ("poisson_6x", 6.0, 20),
    ] {
        let rps = mult * capacity_rps;
        let gaps = poisson_gaps(n, rps, 0x10AD + mult as u64);
        records.push(run_phase(&addr, label, rps, &gaps, &[1], slo_e2e, None));
    }

    let n_burst = if smoke { 6 } else { 12 };
    records.push(run_phase(
        &addr,
        "burst",
        f64::INFINITY,
        &vec![Duration::ZERO; n_burst],
        &[1],
        slo_e2e,
        None,
    ));

    if !smoke {
        let rps = capacity_rps;
        let gaps = poisson_gaps(10, rps, 0xBEEF);
        records.push(run_phase(&addr, "mixed_steps", rps, &gaps, &[1, 1, 1, 2, 4], slo_e2e, None));
    }

    // Webhook phase: sequential submissions (each polled to terminal
    // before the next create, so every one meets an empty queue and is
    // admitted) against a fault-injecting loopback receiver. One
    // scripted 503 forces a live retry through the backoff schedule.
    let receiver = FaultReceiver::start(vec![Fault::Status(503)]).expect("bind webhook receiver");
    let hook_url = receiver.url("/completions");
    let n_hooks = if smoke { 3 } else { 6 };
    let mut hook_latencies = Vec::new();
    for i in 0..n_hooks {
        let prompt = format!("webhook request {i}");
        match submit_and_wait(&addr, &prompt, 5000 + i as u64, 1, Some(&hook_url)) {
            Outcome::Finished { latency_seconds, state } => {
                assert_eq!(state, RunnerState::Succeeded.name(), "webhook request {i}");
                hook_latencies.push(latency_seconds);
            }
            _ => panic!("webhook request {i} refused — sequential creates must be admitted"),
        }
    }
    let webhook_admitted = n_hooks;
    hook_latencies.sort_by(f64::total_cmp);
    records.push(PhaseRecord {
        phase: "webhook".into(),
        offered_rps: 0.0,
        requests: n_hooks,
        admitted: n_hooks,
        succeeded: n_hooks,
        rejected: 0,
        errors: 0,
        p50_seconds: percentile(&hook_latencies, 50.0),
        p99_seconds: percentile(&hook_latencies, 99.0),
        slo_seconds: slo_e2e,
    });

    if smoke {
        smoke_cancel_round_trip(&addr);
    }

    let mut t = Table::new(
        "HTTP serving under offered load",
        &["phase", "offered r/s", "reqs", "admitted", "429", "err", "p50", "p99", "rej %"],
    );
    for r in &records {
        t.row(&[
            r.phase.clone(),
            if r.offered_rps.is_finite() { format!("{:.1}", r.offered_rps) } else { "∞".into() },
            format!("{}", r.requests),
            format!("{}", r.admitted),
            format!("{}", r.rejected),
            format!("{}", r.errors),
            format!("{:.0} ms", r.p50_seconds * 1e3),
            format!("{:.0} ms", r.p99_seconds * 1e3),
            format!("{:.0}", 100.0 * r.rejection_rate()),
        ]);
    }
    t.print();

    // The backpressure contract: overload sheds, and what is admitted
    // stays inside the end-to-end SLO.
    let overload = records.iter().find(|r| r.phase == "poisson_6x").expect("overload phase ran");
    assert!(
        overload.rejected > 0,
        "6x overload against an 8-deep queue must shed some requests"
    );
    for r in &records {
        assert_eq!(r.errors, 0, "phase {}: transport/protocol errors", r.phase);
        if r.succeeded > 0 {
            assert!(
                r.p99_seconds <= r.slo_seconds,
                "phase {}: admitted p99 {:.3} s exceeds the {:.3} s SLO",
                r.phase,
                r.p99_seconds,
                r.slo_seconds
            );
        }
    }
    println!(
        "\nbackpressure holds: {}/{} overload arrivals shed (429), p99 {:.0} <= SLO {:.0} ms",
        overload.rejected,
        overload.requests,
        overload.p99_seconds * 1e3,
        overload.slo_seconds * 1e3
    );

    // Graceful shutdown via the signal path (the in-process equivalent
    // of SIGTERM), then the drained report.
    shutdown::request_shutdown();
    let report = server.run_until_signalled();
    let served: usize = records.iter().map(|r| r.admitted).sum();
    assert!(report.outcomes.len() >= served, "drained report covers every admitted request");
    if let Some(lat) = report.succeeded_latency_summary() {
        println!(
            "server-side: {} outcomes, {} rejected, success latency p50 {:.0} ms p99 {:.0} ms",
            report.outcomes.len(),
            report.rejected,
            lat.median * 1e3,
            lat.p99 * 1e3
        );
    }

    // The delivery contract: after the drain (which flushes the
    // webhook queue), every admitted webhook prediction's terminal
    // state was delivered — exactly once each, nothing dead-lettered.
    let wh = &report.webhook;
    assert_eq!(
        wh.enqueued, webhook_admitted as u64,
        "every webhook prediction's terminal transition was enqueued"
    );
    assert_eq!(wh.delivered, webhook_admitted as u64, "deliveries == terminal predictions");
    assert_eq!(wh.dead_lettered, 0, "nothing dead-lettered");
    assert!(wh.retries >= 1, "the scripted 503 forced at least one retry");
    assert_eq!(receiver.delivered_count(), webhook_admitted, "receiver-side count agrees");
    if let Some(lat) = wh.latency_summary() {
        println!(
            "webhook: {}/{} delivered ({} attempts, {} retries), latency p50 {:.0} ms p99 {:.0} ms",
            wh.delivered,
            wh.enqueued,
            wh.attempts,
            wh.retries,
            lat.median * 1e3,
            lat.p99 * 1e3
        );
    }
    receiver.stop();
    emit_json(&records, service_seconds, capacity_rps, &report.webhook);
}
