//! Serve demo: N concurrent prompts through the batched multi-lane
//! serving stack, with per-request latency and aggregate throughput.
//!
//! Run: `cargo run --release --example serve_demo`
//!
//! Backend flags are shared with the `imax-sd` binary (one parser in
//! `util::cli`): `--backend imax|sharded` selects whole-op lane
//! affinity vs single-op row-tile sharding, `--lanes N` sizes the lane
//! pool, `--threads N` the host pool, `--lmm-cache BYTES` the per-lane
//! resident weight cache and `--no-weight-cache` restores the paper's
//! stream-every-call baseline. `--conv-offload on|off` (default on)
//! routes the F16 conv (im2col) GEMMs to the lanes via OP_SML16; `off`
//! restores the paper's quantized-only routing.

use imax_sd::sd::pipeline::{Backend, PipelineConfig};
use imax_sd::sd::QuantModel;
use imax_sd::serve::{ServeConfig, ServeHarness};
use imax_sd::util::cli::{App, BackendFlags, BackendKind};
use imax_sd::util::stats::fmt_duration;
use imax_sd::util::tables::Table;

fn main() {
    let app = App::new("serve_demo", "batched multi-lane serving demo")
        .args(BackendFlags::args());
    let m = app.parse_env();
    let sel = match BackendFlags::parse(&m) {
        Ok(sel) => sel,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    if sel.kind == BackendKind::Host {
        eprintln!("serve_demo always routes through the lane coordinator; use --backend imax or sharded");
        std::process::exit(2);
    }
    let prompts: Vec<(String, u64)> = [
        "a lovely cat",
        "an angry robot",
        "a mountain at dawn",
        "a bowl of ramen",
        "a red bicycle",
        "a lighthouse in fog",
        "a jazz trio on stage",
        "a paper crane",
    ]
    .iter()
    .enumerate()
    .map(|(i, p)| (p.to_string(), 42 + i as u64))
    .collect();

    let serve_cfg = ServeConfig {
        lanes: sel.lanes,
        host_threads: sel.threads,
        max_batch: 4,
        workers: 2,
        sharded: sel.kind == BackendKind::Sharded,
        queue_capacity: 64,
    };
    let mut imax = imax_sd::imax::ImaxConfig::fpga(sel.lanes);
    imax.weight_cache_bytes = sel.cache_bytes;
    let cache_label = if imax.weight_cache_bytes == 0 {
        "off".to_string()
    } else {
        format!("{} KiB/lane", imax.weight_cache_bytes / 1024)
    };
    let harness = ServeHarness::with_imax(
        PipelineConfig {
            weight_seed: 0x5D_7B0,
            model: Some(QuantModel::Q8_0),
            steps: 1,
            backend: Backend::Host { threads: 2 },
            conv_offload: sel.conv_offload,
        },
        serve_cfg,
        imax,
    );
    println!(
        "serving {} prompts: {} lanes ({} routing), {} workers, micro-batch {}, weight cache {}, conv offload {}\n",
        prompts.len(),
        harness.config.lanes,
        if harness.config.sharded { "sharded" } else { "affinity" },
        harness.config.workers,
        harness.config.max_batch,
        cache_label,
        if sel.conv_offload { "on" } else { "off" }
    );

    let report = harness.serve(&prompts);

    let mut t = Table::new(
        "Per-request results",
        &["id", "prompt", "latency", "ops", "MMACs", "image crc32"],
    );
    for o in &report.outcomes {
        t.row(&[
            format!("{}", o.id.0),
            o.prompt.clone(),
            fmt_duration(o.latency_seconds),
            format!("{}", o.matmul_calls),
            format!("{:.1}", o.macs as f64 / 1e6),
            format!("{:08x}", o.image_crc32),
        ]);
    }
    t.print();

    let lat = report.latency_summary();
    let ord = std::sync::atomic::Ordering::Relaxed;
    let metrics = harness.coordinator().metrics.as_ref();
    println!("\naggregate:");
    println!("  wall time            : {}", fmt_duration(report.wall_seconds));
    println!(
        "  throughput           : {:.2} req/s, {:.3e} MAC/s",
        report.requests_per_second(),
        report.macs_per_second()
    );
    println!(
        "  latency              : mean {}  p95 {}  p99 {}",
        fmt_duration(lat.mean),
        fmt_duration(lat.p95),
        fmt_duration(lat.p99)
    );
    println!(
        "  lane submissions     : {} ({} merged, {} jobs coalesced, {} sharded ops over {} shards)",
        report.lane_submissions,
        report.batched_submissions,
        report.coalesced_jobs,
        metrics.sharded_ops.load(ord),
        metrics.shard_submissions.load(ord),
    );
    println!(
        "  lane efficiency      : {:.4} simulated cycles per offloaded MAC",
        report.cycles_per_offloaded_mac()
    );
    println!(
        "  weight residency     : {} B LOAD skipped, {} B missed ({:.0} % byte hit rate)",
        report.cache_hit_bytes,
        report.cache_miss_bytes,
        100.0 * report.cache_byte_hit_rate()
    );
    println!("\nimages are deterministic: same prompt+seed always gives the same crc32");
}
