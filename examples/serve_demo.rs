//! Serve demo: N concurrent prompts through the batched multi-lane
//! serving stack, with per-request latency and aggregate throughput.
//!
//! Run: `cargo run --release --example serve_demo`
//!
//! Flags: `--no-weight-cache` disables LMM weight residency (the paper's
//! stream-every-call baseline), `--lmm-cache BYTES` sizes the per-lane
//! cache partition (default 262144).

use imax_sd::imax::ImaxConfig;
use imax_sd::sd::pipeline::{Backend, PipelineConfig};
use imax_sd::sd::QuantModel;
use imax_sd::serve::{ServeConfig, ServeHarness};
use imax_sd::util::cli::{App, Arg};
use imax_sd::util::stats::fmt_duration;
use imax_sd::util::tables::Table;

fn main() {
    let app = App::new("serve_demo", "batched multi-lane serving demo")
        .arg(
            Arg::opt("lmm-cache", 'c', "BYTES", "LMM bytes reserved as resident weight cache")
                .default("262144"),
        )
        .arg(Arg::flag("no-weight-cache", '\0', "disable weight residency"));
    let m = app.parse_env();
    let prompts: Vec<(String, u64)> = [
        "a lovely cat",
        "an angry robot",
        "a mountain at dawn",
        "a bowl of ramen",
        "a red bicycle",
        "a lighthouse in fog",
        "a jazz trio on stage",
        "a paper crane",
    ]
    .iter()
    .enumerate()
    .map(|(i, p)| (p.to_string(), 42 + i as u64))
    .collect();

    let serve_cfg = ServeConfig { lanes: 4, host_threads: 4, max_batch: 4, workers: 2 };
    let mut imax = ImaxConfig::fpga(serve_cfg.lanes);
    imax.weight_cache_bytes = if m.flag("no-weight-cache") {
        0
    } else {
        match m.usize("lmm-cache") {
            Ok(bytes) => bytes,
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
    };
    let cache_label = if imax.weight_cache_bytes == 0 {
        "off".to_string()
    } else {
        format!("{} KiB/lane", imax.weight_cache_bytes / 1024)
    };
    let harness = ServeHarness::with_imax(
        PipelineConfig {
            weight_seed: 0x5D_7B0,
            model: Some(QuantModel::Q8_0),
            steps: 1,
            backend: Backend::Host { threads: 2 },
        },
        serve_cfg,
        imax,
    );
    println!(
        "serving {} prompts: {} lanes, {} workers, micro-batch {}, weight cache {}\n",
        prompts.len(),
        harness.config.lanes,
        harness.config.workers,
        harness.config.max_batch,
        cache_label
    );

    let report = harness.serve(&prompts);

    let mut t = Table::new(
        "Per-request results",
        &["id", "prompt", "latency", "mat-muls", "MMACs", "image crc32"],
    );
    for o in &report.outcomes {
        t.row(&[
            format!("{}", o.id.0),
            o.prompt.clone(),
            fmt_duration(o.latency_seconds),
            format!("{}", o.matmul_calls),
            format!("{:.1}", o.macs as f64 / 1e6),
            format!("{:08x}", o.image_crc32),
        ]);
    }
    t.print();

    let lat = report.latency_summary();
    println!("\naggregate:");
    println!("  wall time            : {}", fmt_duration(report.wall_seconds));
    println!(
        "  throughput           : {:.2} req/s, {:.3e} MAC/s",
        report.requests_per_second(),
        report.macs_per_second()
    );
    println!(
        "  latency              : mean {}  p95 {}",
        fmt_duration(lat.mean),
        fmt_duration(lat.p95)
    );
    println!(
        "  lane submissions     : {} ({} merged, {} jobs coalesced)",
        report.lane_submissions, report.batched_submissions, report.coalesced_jobs
    );
    println!(
        "  lane efficiency      : {:.4} simulated cycles per offloaded MAC",
        report.cycles_per_offloaded_mac()
    );
    println!(
        "  weight residency     : {} B LOAD skipped, {} B missed ({:.0} % byte hit rate)",
        report.cache_hit_bytes,
        report.cache_miss_bytes,
        100.0 * report.cache_byte_hit_rate()
    );
    println!("\nimages are deterministic: same prompt+seed always gives the same crc32");
}
