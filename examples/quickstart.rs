//! Quickstart: the three-layer stack in one page.
//!
//! 1. quantize a weight matrix with the GGML substrate (L3 host),
//! 2. run the same mat-mul three ways — host kernels, the IMAX lane
//!    simulator (bit-exact hardware dataflow), and the AOT Pallas
//!    artifact via PJRT (when built with `--features pjrt` and
//!    `make artifacts` has run) —
//! 3. print timings and agreement.
//!
//! Run: `cargo run --release --example quickstart`

use imax_sd::ggml::q8_0::BlockQ8_0;
use imax_sd::ggml::{mul_mat, DType, Tensor};
use imax_sd::imax::lane::LaneSim;
use imax_sd::imax::ImaxConfig;
use imax_sd::util::rng::Xoshiro256pp;

fn random(rows: usize, cols: usize, seed: u64) -> Tensor {
    let mut r = Xoshiro256pp::seed_from_u64(seed);
    let mut v = vec![0.0f32; rows * cols];
    r.fill_normal(&mut v, 0.7);
    Tensor::f32(rows, cols, v)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let (m, n, k) = (64usize, 32usize, 256usize);
    let w = random(m, k, 1);
    let x = random(n, k, 2);
    println!("mul_mat: W[{m}x{k}] (Q8_0) x X[{n}x{k}] -> out[{n}x{m}]\n");

    // 1) Host GGML kernel.
    let wq = w.quantize(DType::Q8_0);
    let t0 = std::time::Instant::now();
    let host = mul_mat(&wq, &x, 2);
    println!("host ggml kernel     : {:>10.1?}", t0.elapsed());

    // 2) IMAX lane simulator (functional, cycle-counted).
    let blocks = match &wq.data {
        imax_sd::ggml::tensor::Storage::Q8_0(b) => b.clone(),
        _ => unreachable!(),
    };
    let acts: Vec<_> = (0..n)
        .flat_map(|r| imax_sd::ggml::q8_0::quantize_row(x.row_f32(r)))
        .collect();
    let mut lane = LaneSim::new(ImaxConfig::fpga(1));
    let t0 = std::time::Instant::now();
    let (sim, bd) = lane.mul_mat_q8_0(&blocks, m, &acts, n, k)?;
    println!(
        "imax lane simulator  : {:>10.1?}   ({} cycles = {:.1} µs @145 MHz)",
        t0.elapsed(),
        bd.total(),
        bd.total() as f64 / 145.0
    );
    let exact = host
        .as_f32()
        .iter()
        .zip(&sim)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    println!("  bit-exact vs host  : {exact}");
    assert!(exact);

    // 3) PJRT artifact (the L1 Pallas kernel AOT-compiled by jax).
    run_pjrt(&host, &blocks, &acts, m, n, k)?;
    println!("\nquickstart OK");
    Ok(())
}

/// Execute the Q8_0 artifact through PJRT and compare against the host.
#[cfg(feature = "pjrt")]
fn run_pjrt(
    host: &Tensor,
    blocks: &[BlockQ8_0],
    acts: &[BlockQ8_0],
    m: usize,
    n: usize,
    k: usize,
) -> Result<(), Box<dyn std::error::Error>> {
    match imax_sd::runtime::find_artifact_dir() {
        Some(dir) => {
            let mut rt = imax_sd::runtime::ArtifactRuntime::new(dir)?;
            let exe = rt.load("q8_0_matmul.hlo.txt")?;
            let mut qs = Vec::new();
            let mut d = Vec::new();
            for b in blocks {
                qs.extend_from_slice(&b.qs);
                d.push(b.d.to_f32());
            }
            let mut aqs = Vec::new();
            let mut ad = Vec::new();
            for b in acts {
                aqs.extend_from_slice(&b.qs);
                ad.push(b.d.to_f32());
            }
            use imax_sd::runtime::client::{literal_f32, literal_i8};
            let t0 = std::time::Instant::now();
            let out = exe.run_f32(&[
                literal_i8(&qs, m, k)?,
                literal_f32(&d, m, k / 32)?,
                literal_i8(&aqs, n, k)?,
                literal_f32(&ad, n, k / 32)?,
            ])?;
            println!("pjrt pallas artifact : {:>10.1?}", t0.elapsed());
            let max_err = host
                .as_f32()
                .iter()
                .zip(&out)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            println!("  max |pallas-host|  : {max_err:.2e}");
            assert!(max_err < 1e-3);
        }
        None => println!("pjrt pallas artifact : skipped (run `make artifacts`)"),
    }
    Ok(())
}

/// Stub when the `pjrt` feature is off (the default, offline build).
#[cfg(not(feature = "pjrt"))]
fn run_pjrt(
    _host: &Tensor,
    _blocks: &[BlockQ8_0],
    _acts: &[BlockQ8_0],
    _m: usize,
    _n: usize,
    _k: usize,
) -> Result<(), Box<dyn std::error::Error>> {
    println!("pjrt pallas artifact : skipped (build with --features pjrt)");
    Ok(())
}
