//! **Fig. 5 driver / end-to-end validation**: generate images with the
//! mini SD pipeline for both quantized models, offloading the quantized
//! mat-muls to the IMAX lane simulator, and write PNGs + the run report.
//!
//! Run: `cargo run --release --example generate_image`
//! Output: `fig5_q3_k.png`, `fig5_q8_0.png` (128×128 RGB).

use imax_sd::imax::ImaxConfig;
use imax_sd::sd::pipeline::{to_rgb8, Backend, Pipeline, PipelineConfig};
use imax_sd::sd::QuantModel;
use imax_sd::util::png::{write_png, ColorType};
use imax_sd::util::stats::fmt_duration;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let prompt = "a lovely cat";
    println!("Fig. 5: prompt = {prompt:?}, 1 denoising step (SD-Turbo mode)\n");
    for model in [QuantModel::Q3K, QuantModel::Q8_0] {
        let pipe = Pipeline::new(PipelineConfig {
            weight_seed: 0x5D_7B0,
            model: Some(model),
            steps: 1,
            backend: Backend::Imax { config: ImaxConfig::fpga(1), threads: 2 },
        });
        let (img, report) = pipe.generate(prompt, 42);
        let path = format!("fig5_{}.png", model.name().to_lowercase());
        write_png(&path, img.w as u32, img.h as u32, ColorType::Rgb, &to_rgb8(&img))?;
        println!("== {} model -> {path}", model.name());
        println!("   wall time           : {}", fmt_duration(report.wall_seconds));
        println!(
            "   mat-muls             : {} total, {} offloaded to IMAX",
            report.matmul_calls, report.offloaded_calls
        );
        println!(
            "   simulated IMAX time  : {} ({} cycles @145 MHz)",
            fmt_duration(report.imax_phases.total() as f64 / report.imax_clock_hz),
            report.imax_phases.total()
        );
        let total_macs: u64 = report.macs_by_dtype.iter().map(|(_, v)| v).sum();
        for (dtype, macs) in &report.macs_by_dtype {
            println!(
                "   {dtype:<5} {:>7.1} MMACs ({:>4.1} %)",
                *macs as f64 / 1e6,
                100.0 * *macs as f64 / total_macs as f64
            );
        }
        println!();
    }
    println!("images are deterministic: same prompt+seed reproduces the same PNG bytes");
    Ok(())
}
