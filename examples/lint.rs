//! Project concurrency lint, gating in CI:
//!
//! ```text
//! cargo run --example lint            # exit 0 = clean, 1 = findings
//! ```
//!
//! Walks every `*.rs` under `rust/src/` and enforces the four project
//! invariants documented in `imax_sd::check::lint`: predicate loops
//! around condvar waits, no raw `std::sync` primitives outside the
//! shim, the lock-poisoning policy, and submit/sync pairing. Findings
//! print one per line as `path:line: [rule] message` so editors and CI
//! annotations can jump straight to them.

use std::path::PathBuf;

fn main() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
    let findings = match imax_sd::check::lint::lint_tree(&root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("lint: cannot walk {}: {e}", root.display());
            std::process::exit(2);
        }
    };
    if findings.is_empty() {
        println!("lint: 0 findings under {}", root.display());
        return;
    }
    for f in &findings {
        println!("{f}");
    }
    eprintln!("lint: {} finding(s)", findings.len());
    std::process::exit(1);
}
